//! Hand-rolled CLI (the vendored crate set has no clap).
//!
//! ```text
//! repro <command> [--seqs N] [--seed S] [--target gp104|amd-fiji]
//!                 [--perms N] [--draws N] [--jobs N] [--out DIR] [--full]
//!                 [--verify-each] [--shard I/N] [--emit-summary PATH]
//!                 [--strategy fixed|permute|hillclimb|knn|bandit|genetic]
//!                 [--budget N] [--k K] [--seq p1,p2,...] [--store DIR]
//!                 [--max-mb N] [--objective time|energy|size|pareto]
//!                 [--per-kernel] [--family F]
//!
//! commands: explore rank merge transfer serve cache bench lower fig2
//!           table1 fig3 fig4 fig5 fig6 fig7 problems amd all passes
//!           targets
//! ```
//!
//! `explore` runs the DSE under the selected search strategy
//! (optionally one shard of the fixed-stream grid), `rank` runs the
//! equal-budget strategy arena ([`crate::dse::learn`]), `merge` folds
//! shard files back together, and `transfer` cross-evaluates every
//! target's winning orders on every other target (the §3.1 experiment).
//! `--store DIR` makes all three read-through and persist the on-disk
//! artifact store ([`crate::dse::store`]); `serve` answers NDJSON
//! explore/transfer queries from the warm store, and `cache stats|gc`
//! inspect and bound it — see `docs/CLI.md` for walkthroughs.

use std::path::PathBuf;

use super::experiments::{
    fig2_table1, fig3_cross, fig4_scatter, fig5_permutations, fig6_load_patterns, fig7_features,
    problem_stats, transfer_matrix, ExpConfig, ExpCtx, Fig2Row,
};
use super::report;
use crate::dse::shard::{merge_shards_obj, ShardRun, ShardSpec};
use crate::dse::strategy::StrategyKind;
use crate::dse::{CacheShards, EvalContext, Objective, Store};
use crate::sim::target::Target;
use crate::util::{emit_json, load_json};

pub struct CliArgs {
    pub command: String,
    pub cfg: ExpConfig,
    pub out: PathBuf,
    /// positional arguments after the command — only `merge` takes any
    /// (the shard files to fold)
    pub files: Vec<PathBuf>,
    /// `--emit-summary PATH`: `explore` writes its (mergeable) shard
    /// file here; `merge` writes the folded summaries
    pub emit_summary: Option<PathBuf>,
    /// `lower`'s positional benchmark name
    pub bench: String,
    /// `--seq p1,p2,…`: the phase order `lower` applies before lowering
    /// (validated against the pass registry at parse time); `None` = the
    /// unoptimized build
    pub lower_seq: Option<Vec<&'static str>>,
    /// `cache`'s positional action (`stats` or `gc`)
    pub cache_action: String,
    /// `--max-mb N`: the `cache gc` size budget (default 256)
    pub max_mb: Option<u64>,
    /// `bench`'s positional action (only `list` for now)
    pub bench_action: String,
    /// `--family F`: restrict `bench list` to one benchmark family
    pub family: Option<String>,
}

pub fn parse_args(argv: &[String]) -> Result<CliArgs, String> {
    let mut command = String::new();
    let mut cfg = ExpConfig::default();
    let mut out = PathBuf::from("results");
    let mut files = Vec::new();
    let mut emit_summary = None;
    let mut bench = String::new();
    let mut lower_seq: Option<Vec<&'static str>> = None;
    let mut cache_action = String::new();
    let mut max_mb = None;
    let mut bench_action = String::new();
    let mut family = None;
    let (mut strategy_set, mut budget_set, mut k_set, mut seqs_set) = (false, false, false, false);
    let mut target_set = false;
    let mut objective_set = false;
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--seqs" => {
                cfg.n_seqs = it
                    .next()
                    .ok_or("--seqs needs a value")?
                    .parse()
                    .map_err(|e| format!("--seqs: {e}"))?;
                seqs_set = true;
            }
            "--seed" => {
                cfg.seed = it
                    .next()
                    .ok_or("--seed needs a value")?
                    .parse()
                    .map_err(|e| format!("--seed: {e}"))?
            }
            "--perms" => {
                cfg.n_perms = it
                    .next()
                    .ok_or("--perms needs a value")?
                    .parse()
                    .map_err(|e| format!("--perms: {e}"))?
            }
            "--draws" => {
                cfg.n_random_draws = it
                    .next()
                    .ok_or("--draws needs a value")?
                    .parse()
                    .map_err(|e| format!("--draws: {e}"))?
            }
            "--jobs" => {
                cfg.jobs = it
                    .next()
                    .ok_or("--jobs needs a value")?
                    .parse()
                    .map_err(|e| format!("--jobs: {e}"))?
            }
            "--target" => {
                let t = it.next().ok_or("--target needs a value")?;
                cfg.target = Target::by_name(t)
                    .ok_or_else(|| format!("unknown target {t} (see `repro targets`)"))?;
                target_set = true;
            }
            "--out" => out = PathBuf::from(it.next().ok_or("--out needs a value")?),
            "--full" => {
                // the paper's full protocol. Sets the stream length, so
                // it participates in the --seqs/--budget ambiguity check
                cfg.n_seqs = 10_000;
                cfg.n_perms = 1000;
                cfg.n_random_draws = 1000;
                seqs_set = true;
            }
            "--verify-each" => cfg.verify_each = true,
            "--strategy" => {
                cfg.strategy = StrategyKind::parse(it.next().ok_or("--strategy needs a value")?)?;
                strategy_set = true;
            }
            "--budget" => {
                cfg.budget = it
                    .next()
                    .ok_or("--budget needs a value")?
                    .parse()
                    .map_err(|e| format!("--budget: {e}"))?;
                if cfg.budget == 0 {
                    return Err("--budget must be >= 1".to_string());
                }
                budget_set = true;
            }
            "--k" => {
                cfg.knn_k = it
                    .next()
                    .ok_or("--k needs a value")?
                    .parse()
                    .map_err(|e| format!("--k: {e}"))?;
                if cfg.knn_k == 0 {
                    return Err("--k must be >= 1".to_string());
                }
                k_set = true;
            }
            "--shard" => {
                cfg.shard = Some(ShardSpec::parse(it.next().ok_or("--shard needs I/N")?)?)
            }
            "--emit-summary" => {
                emit_summary = Some(PathBuf::from(
                    it.next().ok_or("--emit-summary needs a path")?,
                ))
            }
            "--seq" => {
                let spec = it.next().ok_or("--seq needs a comma-separated pass list")?;
                let mut seq = Vec::new();
                for name in spec.split(',').filter(|s| !s.is_empty()) {
                    let resolved = crate::passes::registry_names()
                        .iter()
                        .copied()
                        .find(|n| *n == name)
                        .ok_or_else(|| {
                            format!("--seq: unknown pass {name} (see `repro passes`)")
                        })?;
                    seq.push(resolved);
                }
                lower_seq = Some(seq);
            }
            "--store" => {
                cfg.store = Some(PathBuf::from(it.next().ok_or("--store needs a directory")?))
            }
            "--objective" => {
                cfg.objective = Objective::parse(it.next().ok_or("--objective needs a value")?)?;
                objective_set = true;
            }
            "--max-mb" => {
                max_mb = Some(
                    it.next()
                        .ok_or("--max-mb needs a value")?
                        .parse()
                        .map_err(|e| format!("--max-mb: {e}"))?,
                )
            }
            "--per-kernel" => cfg.per_kernel = true,
            "--bench" => {
                cfg.only = Some(it.next().ok_or("--bench needs a benchmark name")?.to_string())
            }
            "--family" => {
                family = Some(it.next().ok_or("--family needs a value")?.to_string())
            }
            "--help" | "-h" => return Err(usage()),
            flag if flag.starts_with("--") => return Err(format!("unknown flag {flag}\n{}", usage())),
            cmd if command.is_empty() => command = cmd.to_string(),
            extra if command == "merge" => files.push(PathBuf::from(extra)),
            extra if command == "lower" && bench.is_empty() => bench = extra.to_string(),
            extra if command == "cache" && cache_action.is_empty() => {
                cache_action = extra.to_string()
            }
            extra if command == "bench" && bench_action.is_empty() => {
                bench_action = extra.to_string()
            }
            extra => return Err(format!("unexpected argument {extra}\n{}", usage())),
        }
    }
    if command.is_empty() {
        return Err(usage());
    }
    if strategy_set && command != "explore" {
        return Err(format!(
            "--strategy only applies to explore (rank always runs every strategy)\n{}",
            usage()
        ));
    }
    if (budget_set || k_set) && !matches!(command.as_str(), "explore" | "rank") {
        return Err(format!(
            "--budget/--k only apply to explore and rank\n{}",
            usage()
        ));
    }
    if command == "explore" && k_set && cfg.strategy != StrategyKind::Knn {
        return Err(format!(
            "--k is the knn neighbor count; it does nothing under --strategy {} — \
             drop it or switch to --strategy knn",
            cfg.strategy.name()
        ));
    }
    if target_set && command == "transfer" {
        return Err(
            "transfer always evaluates every registered target (see `repro targets`); \
             --target would contradict that — drop it"
                .to_string(),
        );
    }
    if cfg.shard.is_some() && command != "explore" {
        return Err(format!("--shard only applies to explore\n{}", usage()));
    }
    if cfg.shard.is_some() && cfg.strategy != StrategyKind::Fixed {
        return Err(format!(
            "--shard only applies to --strategy fixed (adaptive strategies cannot \
             partition a grid that does not exist up front)\n{}",
            usage()
        ));
    }
    if command == "explore" && cfg.strategy == StrategyKind::Fixed && budget_set {
        // for the fixed strategy the budget *is* the stream length;
        // refuse the ambiguous spelling rather than silently preferring
        // one flag over the other. (rank keeps the knobs separate: --seqs
        // is unused there and --budget is the per-benchmark allowance)
        if seqs_set && cfg.n_seqs != cfg.budget {
            return Err(
                "--seqs and --budget are the same knob for --strategy fixed (the stream \
                 length); pass one of them"
                    .to_string(),
            );
        }
        cfg.n_seqs = cfg.budget;
    }
    if emit_summary.is_some() && command != "explore" && command != "merge" {
        return Err(format!(
            "--emit-summary only applies to explore and merge\n{}",
            usage()
        ));
    }
    if cfg.shard.is_some_and(|s| s.count > 1) && emit_summary.is_none() {
        return Err(
            "--shard without --emit-summary would throw the shard's work away; \
             add --emit-summary PATH"
                .to_string(),
        );
    }
    if emit_summary.is_some() && command == "explore" && cfg.strategy != StrategyKind::Fixed {
        return Err(
            "--emit-summary requires --strategy fixed: shard files describe the shared \
             fixed stream, which adaptive strategies do not have"
                .to_string(),
        );
    }
    if objective_set && !matches!(command.as_str(), "explore" | "rank" | "merge" | "serve") {
        return Err(format!(
            "--objective only applies to explore, rank, merge, and serve (the figure \
             drivers reproduce the paper's time-only protocol)\n{}",
            usage()
        ));
    }
    if lower_seq.is_some() && command != "lower" {
        return Err(format!("--seq only applies to lower\n{}", usage()));
    }
    if command == "lower" && bench.is_empty() {
        return Err(format!(
            "lower needs a benchmark name (e.g. `repro lower GEMM`)\n{}",
            usage()
        ));
    }
    if cfg.store.is_some()
        && !matches!(
            command.as_str(),
            "explore" | "transfer" | "merge" | "serve" | "cache"
        )
    {
        return Err(format!(
            "--store only applies to explore, transfer, merge, serve, and cache\n{}",
            usage()
        ));
    }
    if matches!(command.as_str(), "serve" | "cache") && cfg.store.is_none() {
        return Err(format!("{command} requires --store DIR\n{}", usage()));
    }
    if command == "cache" && !matches!(cache_action.as_str(), "stats" | "gc") {
        return Err(format!(
            "cache needs an action: `repro cache stats|gc --store DIR`\n{}",
            usage()
        ));
    }
    if max_mb.is_some() && !(command == "cache" && cache_action == "gc") {
        return Err(format!("--max-mb only applies to cache gc\n{}", usage()));
    }
    if cfg.per_kernel {
        if command != "explore" {
            return Err(format!("--per-kernel only applies to explore\n{}", usage()));
        }
        if cfg.strategy != StrategyKind::Fixed {
            return Err(
                "--per-kernel requires --strategy fixed: the per-kernel search prices \
                 the shared stream's validated sequences, which adaptive strategies do \
                 not have"
                    .to_string(),
            );
        }
        if cfg.shard.is_some() {
            return Err(
                "--per-kernel needs the whole grid's verdicts in one process; \
                 drop --shard (run it on the unsharded explore)"
                    .to_string(),
            );
        }
    }
    if let Some(name) = &cfg.only {
        if !matches!(command.as_str(), "explore" | "rank") {
            return Err(format!("--bench only applies to explore and rank\n{}", usage()));
        }
        if crate::bench_suite::benchmark_by_name(name).is_none() {
            return Err(crate::bench_suite::unknown_benchmark_error(name));
        }
    }
    if command == "bench" && bench_action != "list" {
        return Err(format!(
            "bench needs an action: `repro bench list [--family F]`\n{}",
            usage()
        ));
    }
    if family.is_some() && command != "bench" {
        return Err(format!("--family only applies to bench list\n{}", usage()));
    }
    Ok(CliArgs {
        command,
        cfg,
        out,
        files,
        emit_summary,
        bench,
        lower_seq,
        cache_action,
        max_mb,
        bench_action,
        family,
    })
}

pub fn usage() -> String {
    "usage: repro <explore|rank|merge|transfer|serve|cache|bench|lower|fig2|table1|fig3|fig4|fig5|\
     fig6|fig7|problems|amd|all|passes|targets> \
     [--seqs N] [--seed S] [--target gp104|amd-fiji|host] [--perms N] [--draws N] \
     [--jobs N] [--out DIR] [--full] [--verify-each] [--shard I/N] \
     [--emit-summary PATH] [--strategy fixed|permute|hillclimb|knn|bandit|genetic] \
     [--budget N] [--k K] [--seq p1,p2,...] [--store DIR] [--max-mb N] \
     [--objective time|energy|size|pareto] [--per-kernel] [--bench NAME] [--family F]\n\
     --jobs = evaluation worker threads (0 = all cores, the default); \
     results are bit-identical for every value\n\
     --seed S = the exploration seed (default 0xC0FFEE); drives the shared \
     stream and every adaptive/learned strategy's PRNGs — same seed and \
     budget reproduce identical summaries\n\
     --full = the paper's protocol (10000 sequences, 1000 permutations/draws)\n\
     --verify-each = verify the IR after every changing pass of every \
     evaluated sequence (slow; pinpoints the offending pass)\n\
     --strategy = the search strategy explore drives (default fixed = the \
     shared random stream); permute/hillclimb/knn are adaptive, \
     bandit/genetic are the learned strategies (see docs/CLI.md)\n\
     --budget N = evaluations per benchmark for adaptive strategies and \
     rank (default: --seqs); for --strategy fixed it is the stream length\n\
     --k K = neighbor count for --strategy knn and rank's knn entry \
     (default 3; the paper reports K=1 and K=3); rejected under other \
     strategies\n\
     --shard I/N = evaluate the I-th of N slices of the (benchmark x sequence) \
     grid (explore with --strategy fixed only; requires --emit-summary)\n\
     --objective time|energy|size|pareto = what the winner fold minimizes \
     (explore, merge, serve; default time). energy/size pick the winner by \
     modelled energy or allocated code size; pareto keeps time winners and \
     renders the per-benchmark non-dominated front. The evaluation grid and \
     every cache are objective-independent\n\
     --emit-summary PATH = explore: write the mergeable shard JSON \
     (compact stream-descriptor form); merge: write the folded summaries \
     JSON\n\
     explore = run the DSE under the selected strategy and print \
     per-benchmark summaries (the raw engine, no figure post-processing)\n\
     rank = the equal-budget strategy arena: run fixed, hillclimb, knn, \
     bandit, and genetic over the same benchmarks at --budget (default \
     --seqs) evaluations per benchmark each, print the per-strategy \
     geomean ranking, and write rank.json under --out\n\
     merge <shard.json>... = fold shard files from sharded explore runs \
     (descriptor or legacy full-stream form, or a mix); bit-identical to \
     the equivalent single-process explore\n\
     --store DIR = warm both cache levels from the on-disk artifact \
     store before exploring and persist them back after (explore, \
     transfer, merge; epoch-stale entries are re-evaluated incrementally)\n\
     transfer = the §3.1 cross-device experiment: explore on every \
     registered target, then compile each winning order ONCE and \
     measure/validate it on every target (rejects --target; writes \
     transfer.json under --out)\n\
     serve = daemon loop answering newline-delimited JSON explore/\
     transfer/stats queries on stdin from the warm store (requires \
     --store DIR)\n\
     cache stats|gc = print the store's per-table entry counts, bytes \
     and epochs, or evict oldest-generation tables past --max-mb N \
     (default 256; requires --store DIR)\n\
     bench list [--family F] = list the benchmark registry (name, family, \
     dataset dims, kernel count), optionally one family only\n\
     --per-kernel = after a fixed-stream explore, additionally search a \
     winning order PER KERNEL of every multi-kernel benchmark and report \
     the stitched program against the one-shared-order winner (writes \
     per_kernel.json under --out)\n\
     --bench NAME = restrict explore to one benchmark (case-insensitive; \
     see `repro bench list` for the registry)\n\
     lower <bench> [--seq p1,p2,...] [--target T] = print the allocated \
     vPTX of one benchmark (optionally after a phase order) plus \
     per-kernel regs/spills/occupancy — the register-allocation debug \
     view\n\
     passes = list the registry (name, kind, preserved analyses)\n\
     targets = list the registered device models (--target values)"
        .to_string()
}

/// `repro targets` — the device-model registry listing: every `--target`
/// value, its cost-table identity, and the headline hardware numbers.
fn render_targets() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<26} {:>7} {:>10} {:>14} {:>12}  aliases\n",
        "name", "kind", "SMs/CUs", "clock", "gpr/pred/max", "warps/SM"
    ));
    for t in Target::all() {
        out.push_str(&format!(
            "{:<14} {:<26} {:>7} {:>7.2}GHz {:>14} {:>12}  {}\n",
            t.name,
            t.kind.describe(),
            t.sms as u32,
            t.clock_ghz,
            format!("{}/{}/{}", t.regs.gpr, t.regs.pred, t.regs.max_per_thread),
            format!(
                "{}-{}",
                t.min_resident_warps as u32, t.max_warps_per_sm as u32
            ),
            t.aliases().join(", ")
        ));
    }
    out
}

/// `repro passes` — the registry listing: name, transform vs analysis,
/// and the declared preserve contract of each pass.
fn render_passes() -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:<10} preserves-on-change\n",
        "name", "kind"
    ));
    for &p in crate::passes::registry_ref() {
        let kind = if p.is_analysis() { "analysis" } else { "transform" };
        let preserved = p.preserves_on_change();
        let pres = if preserved.is_empty() {
            "(none)".to_string()
        } else {
            preserved
                .iter()
                .map(|a| a.name())
                .collect::<Vec<_>>()
                .join(", ")
        };
        out.push_str(&format!("{:<22} {:<10} {}\n", p.name(), kind, pres));
    }
    out
}

fn fig2_cached(ctx: &mut ExpCtx) -> Vec<Fig2Row> {
    eprintln!(
        "exploring {} sequences × {} benchmarks on {} with {} worker(s) (golden: {}) …",
        ctx.cfg.n_seqs,
        ctx.benchmarks.len(),
        ctx.cfg.target.name,
        crate::dse::engine::resolve_jobs(ctx.cfg.jobs),
        if ctx.used_pjrt_golden { "AOT artifacts" } else { "interpreter" }
    );
    fig2_table1(ctx)
}

pub fn run(args: CliArgs) -> Result<(), String> {
    let out = args.out.clone();
    let io = |e: std::io::Error| e.to_string();
    match args.command.as_str() {
        // registry listing and fig6 need no exploration context — handle
        // them before the (expensive) per-benchmark golden/baseline build
        "passes" => {
            print!("{}", render_passes());
        }
        "targets" => {
            print!("{}", render_targets());
        }
        // `repro bench list` — the benchmark registry listing
        "bench" => {
            print!("{}", report::render_benches(args.family.as_deref()));
        }
        // `repro lower` — the backend debug view: allocated vPTX plus
        // the per-kernel allocation stats the cost model prices
        "lower" => {
            let b = crate::bench_suite::benchmark_by_name(&args.bench)
                .ok_or_else(|| format!("unknown benchmark {}", args.bench))?;
            let mut built = b.build_full(crate::bench_suite::Variant::OpenCl);
            let seq: Vec<&'static str> = args.lower_seq.clone().unwrap_or_default();
            if !seq.is_empty() {
                let mut am = crate::passes::AnalysisManager::new();
                match crate::passes::run_sequence_with(&mut built.module, &seq, false, &mut am) {
                    crate::passes::PassOutcome::Ok => {}
                    other => {
                        return Err(format!(
                            "lower {}: the phase order failed before lowering: {other:?}",
                            args.bench
                        ))
                    }
                }
            }
            let target = &args.cfg.target;
            println!(
                "{}: {} kernel(s), target {}, order [{}]",
                args.bench,
                built.module.kernels.len(),
                target.name,
                seq.join(", ")
            );
            for k in &built.module.kernels {
                let lk = crate::sim::cost::LoweredKernel::lower(k, &built.module);
                let ak = lk.allocated(target);
                println!("\n{}", ak.prog.text());
                println!(
                    "kernel {}: regs/thread {} spill slots {} (loads {} stores {}) occupancy {:.2}",
                    ak.prog.kernel,
                    ak.stats.regs_per_thread,
                    ak.stats.spill_slots,
                    ak.stats.spill_loads,
                    ak.stats.spill_stores,
                    crate::sim::cost::occupancy(ak.stats.regs_per_thread, target)
                );
            }
        }
        // the store daemon: NDJSON queries over stdin/stdout, answered
        // from (and persisted back into) the warm artifact store
        "serve" => {
            super::serve::serve(&args.cfg)?;
        }
        // store maintenance: inspect table occupancy or bound its size
        "cache" => {
            let dir = args.cfg.store.as_ref().expect("checked at parse time");
            let store = Store::open(dir);
            match args.cache_action.as_str() {
                "stats" => print!("{}", report::render_cache_stats(&store.stats(), dir)),
                "gc" => {
                    let budget = args.max_mb.unwrap_or(256) * 1024 * 1024;
                    print!("{}", report::render_gc(&store.gc(budget), budget));
                }
                _ => unreachable!("validated at parse time"),
            }
        }
        // §3.1 cross-device transfer: explore per target, compile each
        // winning order once, price the artifact everywhere
        "transfer" => {
            let m = transfer_matrix(&args.cfg);
            println!("{}", report::render_transfer(&m));
            report::write_json(&out, "transfer.json", &report::transfer_json(&m)).map_err(io)?;
            eprintln!("wrote {}", out.join("transfer.json").display());
        }
        "fig6" => {
            let (cuda, ocl) = fig6_load_patterns();
            println!("=== Fig. 6(a): 2DCONV lowered CUDA-style (NVCC addressing) ===");
            println!("{}", first_load_window(&cuda));
            println!("=== Fig. 6(b): 2DCONV lowered from OpenCL (naive chain) ===");
            println!("{}", first_load_window(&ocl));
        }
        // `merge` folds shard files — no exploration context needed either
        "merge" => {
            if args.files.is_empty() {
                return Err(format!(
                    "merge needs at least one shard file (written by \
                     `repro explore --emit-summary`)\n{}",
                    usage()
                ));
            }
            let mut shards = Vec::new();
            for f in &args.files {
                let j = load_json(f)?;
                shards.push(ShardRun::from_json(&j).map_err(|e| format!("{}: {e}", f.display()))?);
            }
            let summaries = merge_shards_obj(&shards, args.cfg.objective)?;
            eprintln!(
                "merged {} shard(s): {} sequences × {} benchmarks",
                shards.len(),
                shards[0].n_seqs(),
                summaries.len()
            );
            // merge_shards refused cross-target mixes, so shard 0 names
            // the target every verdict was judged on — the one the
            // winner tables' allocation columns must be computed against
            let target = Target::by_name(&shards[0].target).ok_or_else(|| {
                format!(
                    "shard file target {} is not in the registry (see `repro targets`)",
                    shards[0].target
                )
            })?;
            println!("{}", report::render_explore(&summaries, &target));
            if let Some(path) = &args.emit_summary {
                emit_json(path, &report::summaries_json(&summaries)).map_err(io)?;
            }
            if let Some(dir) = &args.cfg.store {
                // fold the merged evaluations into the store: re-seed a
                // fresh cache per benchmark from (stream × evaluations)
                // through the same first-write-wins path the engine uses
                let store = Store::open(dir);
                let generation = store.bump_generation().map_err(io)?;
                let stream = shards[0].stream.expand(shards[0].seed)?;
                for s in &summaries {
                    let b = crate::bench_suite::benchmark_by_name(&s.bench)
                        .ok_or_else(|| format!("merged benchmark {} is unknown", s.bench))?;
                    let cache = CacheShards::new();
                    for (seq, e) in stream.iter().zip(&s.evaluations) {
                        cache.memo_seq(EvalContext::seq_key(seq), e, target.name);
                    }
                    store.persist(&b, &cache, generation).map_err(io)?;
                }
                eprintln!(
                    "store: persisted {} merged benchmark table(s) to {}",
                    summaries.len(),
                    dir.display()
                );
            }
        }
        // the equal-budget strategy arena (docs/ARCHITECTURE.md §learned
        // search): every shipped strategy, same benchmarks, same budget
        "rank" => {
            let ctx = ExpCtx::new(args.cfg.clone());
            eprintln!(
                "ranking {} strategies at {} evaluations per benchmark × {} benchmarks on {} \
                 with {} worker(s) (golden: {}) …",
                StrategyKind::NAMES.len() - 1, // permute sits the arena out
                ctx.budget_per_bench(),
                ctx.benchmarks.len(),
                ctx.cfg.target.name,
                crate::dse::engine::resolve_jobs(ctx.cfg.jobs),
                if ctx.used_pjrt_golden { "AOT artifacts" } else { "interpreter" }
            );
            let entries = ctx.rank_strategies();
            println!(
                "{}",
                report::render_rank(&entries, &ctx.cfg.target, ctx.budget_per_bench())
            );
            report::write_json(
                &out,
                "rank.json",
                &report::rank_json(
                    &entries,
                    ctx.cfg.target.name,
                    ctx.cfg.seed,
                    ctx.budget_per_bench(),
                ),
            )
            .map_err(io)?;
            eprintln!("wrote {}", out.join("rank.json").display());
        }
        "explore" => {
            let cfg = args.cfg.clone();
            if cfg.strategy != StrategyKind::Fixed {
                // adaptive strategies: no grid, no shard files — run the
                // strategy loop and print what it proposed
                let ctx = ExpCtx::new(cfg);
                eprintln!(
                    "exploring with strategy {} (budget {} per benchmark) × {} benchmarks on {} \
                     with {} worker(s) (golden: {}) …",
                    ctx.cfg.strategy.name(),
                    ctx.budget_per_bench(),
                    ctx.benchmarks.len(),
                    ctx.cfg.target.name,
                    crate::dse::engine::resolve_jobs(ctx.cfg.jobs),
                    if ctx.used_pjrt_golden { "AOT artifacts" } else { "interpreter" }
                );
                if matches!(ctx.cfg.strategy, StrategyKind::Permute | StrategyKind::Knn) {
                    // these seed from reference winners, which come from
                    // a full shared-stream exploration first — often the
                    // dominant cost, so say it is happening
                    eprintln!(
                        "reference pool: exploring the {}-sequence shared stream first \
                         (adjust with --seqs) …",
                        ctx.cfg.n_seqs
                    );
                }
                let summaries = ctx.explore_strategy();
                println!(
                    "{}",
                    report::render_explore_strategy(
                        ctx.cfg.strategy.name(),
                        &summaries,
                        &ctx.cfg.target
                    )
                );
                let (seq_memos, ptx_verdicts) = ctx.cache_totals();
                eprintln!(
                    "cache occupancy: {seq_memos} sequence memos, {ptx_verdicts} vPTX verdicts"
                );
                ctx.persist_store().map_err(io)?;
                return Ok(());
            }
            let spec = cfg.shard.unwrap_or_else(ShardSpec::full);
            let ctx = ExpCtx::new(cfg);
            eprintln!(
                "exploring {} sequences × {} benchmarks on {} with {} worker(s), shard {spec} \
                 (golden: {}) …",
                ctx.cfg.n_seqs,
                ctx.benchmarks.len(),
                ctx.cfg.target.name,
                crate::dse::engine::resolve_jobs(ctx.cfg.jobs),
                if ctx.used_pjrt_golden { "AOT artifacts" } else { "interpreter" }
            );
            if spec.count > 1 {
                // partial grid: emit the raw evaluation stream for merge
                // (parse_args guarantees the emit path is present).
                // compact() swaps the embedded stream for the seeded
                // descriptor — the stream is --seed/--seqs-derived here
                let run = ctx.explore_shard().compact()?;
                let path = args.emit_summary.as_ref().expect("checked at parse time");
                emit_json(path, &run.to_json()).map_err(io)?;
                println!(
                    "shard {spec}: {} of {} grid evaluations → {}",
                    run.n_items(),
                    ctx.benchmarks.len() * ctx.stream.len(),
                    path.display()
                );
                ctx.persist_store().map_err(io)?;
            } else {
                let summaries = ctx.explore_all();
                println!("{}", report::render_explore(&summaries, &ctx.cfg.target));
                if ctx.cfg.per_kernel {
                    let reports = super::experiments::per_kernel_reports(&ctx, &summaries);
                    println!("{}", report::render_per_kernel(&reports));
                    report::write_json(&out, "per_kernel.json", &report::per_kernel_json(&reports))
                        .map_err(io)?;
                    eprintln!("wrote {}", out.join("per_kernel.json").display());
                }
                let (seq_memos, ptx_verdicts) = ctx.cache_totals();
                eprintln!(
                    "cache occupancy: {seq_memos} sequence memos, {ptx_verdicts} vPTX verdicts"
                );
                if let Some(path) = &args.emit_summary {
                    // emit the mergeable 1/1 shard form straight from the
                    // summaries in hand (the merge fold is idempotent),
                    // with the stream compacted to its descriptor
                    let run = ctx.package_summaries(&summaries).compact()?;
                    emit_json(path, &run.to_json()).map_err(io)?;
                    eprintln!("wrote {}", path.display());
                }
                ctx.persist_store().map_err(io)?;
            }
        }
        "fig2" | "table1" | "fig3" | "fig4" | "fig5" | "problems" | "fig7" | "amd" | "all" => {
            let mut cfg = args.cfg.clone();
            if args.command == "amd" {
                // same protocol, Fiji cost tables (§3.1 side experiment)
                cfg.target = Target::fiji();
            }
            let mut ctx = ExpCtx::new(cfg);
            let rows = fig2_cached(&mut ctx);
            match args.command.as_str() {
                "fig2" | "amd" => {
                    println!("{}", report::render_fig2(&rows));
                    report::write_json(&out, "fig2.json", &report::fig2_json(&rows)).map_err(io)?;
                }
                "table1" => println!("{}", report::render_table1(&rows)),
                "fig3" => {
                    let m = fig3_cross(&mut ctx, &rows);
                    println!("{}", report::render_fig3(&m));
                    report::write_json(&out, "fig3.json", &report::fig3_json(&m)).map_err(io)?;
                }
                "fig4" => {
                    let f = fig4_scatter(&mut ctx, &rows);
                    println!("{}", report::render_fig4(&f));
                    report::write_json(&out, "fig4.json", &report::fig4_json(&f)).map_err(io)?;
                }
                "fig5" => {
                    let st = fig5_permutations(&mut ctx, &rows);
                    println!("{}", report::render_fig5(&st));
                    report::write_json(&out, "fig5.json", &report::fig5_json(&st)).map_err(io)?;
                }
                "problems" => {
                    let p = problem_stats(&rows, ctx.cfg.n_seqs);
                    println!("{}", report::render_problems(&p));
                }
                "fig7" => {
                    let f = fig7_features(&mut ctx, &rows);
                    println!("{}", report::render_fig7(&f));
                    report::write_json(&out, "fig7.json", &report::fig7_json(&f)).map_err(io)?;
                }
                "all" => {
                    println!("{}", report::render_fig2(&rows));
                    println!("{}", report::render_table1(&rows));
                    report::write_json(&out, "fig2.json", &report::fig2_json(&rows)).map_err(io)?;
                    let m = fig3_cross(&mut ctx, &rows);
                    println!("{}", report::render_fig3(&m));
                    report::write_json(&out, "fig3.json", &report::fig3_json(&m)).map_err(io)?;
                    let f4 = fig4_scatter(&mut ctx, &rows);
                    println!("{}", report::render_fig4(&f4));
                    report::write_json(&out, "fig4.json", &report::fig4_json(&f4)).map_err(io)?;
                    let st = fig5_permutations(&mut ctx, &rows);
                    println!("{}", report::render_fig5(&st));
                    report::write_json(&out, "fig5.json", &report::fig5_json(&st)).map_err(io)?;
                    let p = problem_stats(&rows, ctx.cfg.n_seqs);
                    println!("{}", report::render_problems(&p));
                    let f7 = fig7_features(&mut ctx, &rows);
                    println!("{}", report::render_fig7(&f7));
                    report::write_json(&out, "fig7.json", &report::fig7_json(&f7)).map_err(io)?;
                    let (cuda, ocl) = fig6_load_patterns();
                    println!("=== Fig. 6: load patterns (CUDA vs OpenCL) ===");
                    println!("{}\n{}", first_load_window(&cuda), first_load_window(&ocl));
                }
                _ => unreachable!(),
            }
        }
        other => return Err(format!("unknown command {other}\n{}", usage())),
    }
    Ok(())
}

/// The instructions around the first global load (the Fig. 6 window).
fn first_load_window(ptx: &str) -> String {
    let lines: Vec<&str> = ptx.lines().collect();
    let pos = lines
        .iter()
        .position(|l| l.contains("ld.global"))
        .unwrap_or(0);
    let lo = pos.saturating_sub(5);
    lines[lo..=pos.min(lines.len() - 1)].join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let a = parse_args(&sv(&[
            "fig2", "--seqs", "50", "--seed", "9", "--target", "amd-fiji", "--jobs", "3",
        ]))
        .unwrap();
        assert_eq!(a.command, "fig2");
        assert_eq!(a.cfg.n_seqs, 50);
        assert_eq!(a.cfg.seed, 9);
        assert_eq!(a.cfg.target.name, "amd-fiji");
        assert_eq!(a.cfg.jobs, 3);
    }

    #[test]
    fn jobs_defaults_to_auto() {
        let a = parse_args(&sv(&["fig2"])).unwrap();
        assert_eq!(a.cfg.jobs, 0, "0 = all cores");
        assert!(parse_args(&sv(&["fig2", "--jobs", "x"])).is_err());
    }

    #[test]
    fn full_flag_sets_paper_protocol() {
        let a = parse_args(&sv(&["all", "--full"])).unwrap();
        assert_eq!(a.cfg.n_seqs, 10_000);
        assert_eq!(a.cfg.n_perms, 1000);
    }

    #[test]
    fn rejects_unknown() {
        assert!(parse_args(&sv(&["fig2", "--bogus"])).is_err());
        assert!(parse_args(&sv(&[])).is_err());
    }

    #[test]
    fn shard_flag_parses_and_is_validated() {
        let a = parse_args(&sv(&[
            "explore", "--shard", "2/4", "--emit-summary", "out/s2.json",
        ]))
        .unwrap();
        assert_eq!(a.command, "explore");
        assert_eq!(a.cfg.shard, Some(ShardSpec::new(2, 4).unwrap()));
        assert_eq!(a.emit_summary.as_deref(), Some(std::path::Path::new("out/s2.json")));
        // malformed specs
        for bad in ["0/2", "3/2", "x", "1/0"] {
            assert!(
                parse_args(&sv(&["explore", "--shard", bad, "--emit-summary", "x.json"])).is_err(),
                "{bad} should be rejected"
            );
        }
        // a real shard without an emit path would discard its work
        assert!(parse_args(&sv(&["explore", "--shard", "1/2"])).is_err());
        // 1/1 is the whole grid: printing the table is enough
        assert!(parse_args(&sv(&["explore", "--shard", "1/1"])).is_ok());
        // --shard is an explore-only flag
        assert!(parse_args(&sv(&["fig2", "--shard", "1/2", "--emit-summary", "x.json"])).is_err());
    }

    #[test]
    fn merge_takes_positional_files() {
        let a = parse_args(&sv(&["merge", "a.json", "b.json"])).unwrap();
        assert_eq!(a.command, "merge");
        assert_eq!(
            a.files,
            vec![PathBuf::from("a.json"), PathBuf::from("b.json")]
        );
        // other commands still reject positionals
        assert!(parse_args(&sv(&["fig2", "a.json"])).is_err());
        // --emit-summary is valid on merge, rejected elsewhere
        assert!(parse_args(&sv(&["merge", "a.json", "--emit-summary", "m.json"])).is_ok());
        assert!(parse_args(&sv(&["fig5", "--emit-summary", "m.json"])).is_err());
    }

    #[test]
    fn strategy_flags_parse_and_are_validated() {
        // defaults: fixed strategy, budget 0 (= --seqs), k = 3
        let a = parse_args(&sv(&["explore"])).unwrap();
        assert_eq!(a.cfg.strategy, StrategyKind::Fixed);
        assert_eq!(a.cfg.budget, 0);
        assert_eq!(a.cfg.knn_k, 3);
        // the adaptive strategies parse with their knobs
        let a = parse_args(&sv(&["explore", "--strategy", "hillclimb", "--budget", "64"])).unwrap();
        assert_eq!(a.cfg.strategy, StrategyKind::HillClimb);
        assert_eq!(a.cfg.budget, 64);
        let a = parse_args(&sv(&["explore", "--strategy", "knn", "--k", "1"])).unwrap();
        assert_eq!(a.cfg.strategy, StrategyKind::Knn);
        assert_eq!(a.cfg.knn_k, 1);
        let a = parse_args(&sv(&["explore", "--strategy", "permute", "--budget", "20"])).unwrap();
        assert_eq!(a.cfg.strategy, StrategyKind::Permute);
        // the learned strategies ride the same flags
        let a = parse_args(&sv(&["explore", "--strategy", "bandit", "--budget", "32"])).unwrap();
        assert_eq!(a.cfg.strategy, StrategyKind::Bandit);
        assert_eq!(a.cfg.budget, 32);
        let a = parse_args(&sv(&["explore", "--strategy", "genetic", "--seed", "7"])).unwrap();
        assert_eq!(a.cfg.strategy, StrategyKind::Genetic);
        assert_eq!(a.cfg.seed, 7);
        // --k is the knn neighbor count: pointed rejection elsewhere
        let e = parse_args(&sv(&["explore", "--strategy", "bandit", "--k", "3"])).unwrap_err();
        assert!(e.contains("--strategy bandit"), "{e}");
        assert!(parse_args(&sv(&["explore", "--strategy", "hillclimb", "--k", "2"])).is_err());
        assert!(parse_args(&sv(&["explore", "--k", "2"])).is_err(), "fixed");
        // for the fixed strategy --budget is the stream length
        let a = parse_args(&sv(&["explore", "--strategy", "fixed", "--budget", "77"])).unwrap();
        assert_eq!(a.cfg.n_seqs, 77);
        // …so passing both knobs with different values is ambiguous
        assert!(parse_args(&sv(&[
            "explore", "--strategy", "fixed", "--seqs", "100", "--budget", "50",
        ]))
        .is_err());
        // --full sets the stream length too: shrinking it with --budget
        // must be refused, not silently applied
        assert!(parse_args(&sv(&["explore", "--full", "--budget", "50"])).is_err());
        // for the adaptive strategies the two knobs are independent
        // (--seqs sizes the reference exploration, --budget the search)
        assert!(parse_args(&sv(&[
            "explore", "--strategy", "knn", "--seqs", "100", "--budget", "50",
        ]))
        .is_ok());
        // bad values; the unknown-strategy error lists the full menu
        let e = parse_args(&sv(&["explore", "--strategy", "anneal"])).unwrap_err();
        for name in StrategyKind::NAMES {
            assert!(e.contains(name), "{e} should list {name}");
        }
        assert!(parse_args(&sv(&["explore", "--budget", "0"])).is_err());
        assert!(parse_args(&sv(&["explore", "--k", "0"])).is_err());
        // --strategy is explore-only; --budget/--k also ride on rank
        assert!(parse_args(&sv(&["fig2", "--strategy", "hillclimb"])).is_err());
        assert!(parse_args(&sv(&["fig2", "--budget", "5"])).is_err());
        assert!(parse_args(&sv(&["merge", "a.json", "--k", "3"])).is_err());
        assert!(parse_args(&sv(&["rank", "--strategy", "bandit"])).is_err());
        // sharding partitions the fixed grid only
        assert!(parse_args(&sv(&[
            "explore", "--strategy", "hillclimb", "--shard", "1/2", "--emit-summary", "x.json",
        ]))
        .is_err());
        // shard files embed/describe the fixed stream: adaptive
        // strategies cannot emit them
        assert!(parse_args(&sv(&[
            "explore", "--strategy", "knn", "--emit-summary", "x.json",
        ]))
        .is_err());
    }

    #[test]
    fn rank_flags_parse_and_are_validated() {
        // the arena takes the exploration knobs that size its budget …
        let a = parse_args(&sv(&[
            "rank", "--seqs", "16", "--seed", "29", "--k", "1", "--jobs", "2",
        ]))
        .unwrap();
        assert_eq!(a.command, "rank");
        assert_eq!(a.cfg.n_seqs, 16);
        assert_eq!(a.cfg.seed, 29);
        assert_eq!(a.cfg.knn_k, 1);
        // … and --budget names the per-benchmark allowance directly
        let a = parse_args(&sv(&["rank", "--budget", "24"])).unwrap();
        assert_eq!(a.cfg.budget, 24);
        // --seqs and --budget stay independent knobs here (no fixed-
        // stream ambiguity: rank has no shard grid)
        assert!(parse_args(&sv(&["rank", "--seqs", "100", "--budget", "50"])).is_ok());
        // one benchmark only is a legitimate arena
        assert!(parse_args(&sv(&["rank", "--bench", "GEMM"])).is_ok());
        // strategy selection, sharding, and shard emission stay out
        assert!(parse_args(&sv(&["rank", "--strategy", "genetic"])).is_err());
        assert!(
            parse_args(&sv(&["rank", "--shard", "1/2", "--emit-summary", "x.json"])).is_err()
        );
        assert!(parse_args(&sv(&["rank", "--emit-summary", "x.json"])).is_err());
        assert!(parse_args(&sv(&["rank", "--per-kernel"])).is_err());
    }

    #[test]
    fn objective_flag_parses_and_is_validated() {
        // default: the paper's time-only protocol
        let a = parse_args(&sv(&["explore"])).unwrap();
        assert_eq!(a.cfg.objective, Objective::Time);
        for (name, want) in [
            ("time", Objective::Time),
            ("energy", Objective::Energy),
            ("size", Objective::Size),
            ("pareto", Objective::Pareto),
        ] {
            let a = parse_args(&sv(&["explore", "--objective", name])).unwrap();
            assert_eq!(a.cfg.objective, want, "{name}");
        }
        // merge and serve re-fold under an objective too
        assert!(parse_args(&sv(&["merge", "a.json", "--objective", "pareto"])).is_ok());
        assert!(parse_args(&sv(&["serve", "--store", "st", "--objective", "energy"])).is_ok());
        // unknown objectives fail at parse time with the full menu
        let err = parse_args(&sv(&["explore", "--objective", "carbon"])).unwrap_err();
        assert!(err.contains("time|energy|size|pareto"), "{err}");
        assert!(parse_args(&sv(&["explore", "--objective"])).is_err());
        // figure drivers reproduce the paper's protocol: time only
        assert!(parse_args(&sv(&["fig2", "--objective", "energy"])).is_err());
        assert!(parse_args(&sv(&["transfer", "--objective", "size"])).is_err());
        assert!(parse_args(&sv(&["lower", "GEMM", "--objective", "time"])).is_err());
    }

    #[test]
    fn targets_and_transfer_parse_and_validate() {
        let a = parse_args(&sv(&["targets"])).unwrap();
        assert_eq!(a.command, "targets");
        let a = parse_args(&sv(&["transfer", "--seqs", "16", "--jobs", "2"])).unwrap();
        assert_eq!(a.command, "transfer");
        assert_eq!(a.cfg.n_seqs, 16);
        // transfer always spans every registered target: picking one
        // with --target is a contradiction, not a preference
        assert!(parse_args(&sv(&["transfer", "--target", "gp104"])).is_err());
        // strategy/shard/emit flags stay explore-only
        assert!(parse_args(&sv(&["transfer", "--strategy", "hillclimb"])).is_err());
        assert!(
            parse_args(&sv(&["transfer", "--shard", "1/2", "--emit-summary", "x.json"])).is_err()
        );
        assert!(parse_args(&sv(&["transfer", "--emit-summary", "x.json"])).is_err());
        // --target still works everywhere else
        assert!(parse_args(&sv(&["explore", "--target", "amd-fiji"])).is_ok());
    }

    #[test]
    fn targets_listing_covers_the_registry() {
        let text = render_targets();
        for t in Target::all() {
            assert!(text.contains(t.name), "missing {}", t.name);
            assert!(text.contains(t.kind.describe()), "missing kind of {}", t.name);
            for alias in t.aliases() {
                assert!(text.contains(alias), "missing alias {alias}");
            }
        }
    }

    #[test]
    fn lower_parses_and_is_validated() {
        let a = parse_args(&sv(&["lower", "GEMM"])).unwrap();
        assert_eq!(a.command, "lower");
        assert_eq!(a.bench, "GEMM");
        assert!(a.lower_seq.is_none());
        // --seq resolves against the pass registry at parse time
        let a = parse_args(&sv(&[
            "lower", "ATAX", "--seq", "cfl-anders-aa,licm", "--target", "amd-fiji",
        ]))
        .unwrap();
        assert_eq!(a.bench, "ATAX");
        assert_eq!(a.lower_seq.as_deref(), Some(&["cfl-anders-aa", "licm"][..]));
        assert_eq!(a.cfg.target.name, "amd-fiji");
        // unknown passes are a parse error, not a runtime surprise
        assert!(parse_args(&sv(&["lower", "GEMM", "--seq", "no-such-pass"])).is_err());
        // the benchmark positional is mandatory
        assert!(parse_args(&sv(&["lower"])).is_err());
        // --seq is lower-only; positionals stay rejected elsewhere
        assert!(parse_args(&sv(&["explore", "--seq", "licm"])).is_err());
        assert!(parse_args(&sv(&["fig2", "GEMM"])).is_err());
        // exactly one benchmark: a second positional is an error
        assert!(parse_args(&sv(&["lower", "GEMM", "ATAX"])).is_err());
    }

    #[test]
    fn verify_each_flag_parses() {
        let a = parse_args(&sv(&["fig2", "--verify-each"])).unwrap();
        assert!(a.cfg.verify_each);
        let a = parse_args(&sv(&["fig2"])).unwrap();
        assert!(!a.cfg.verify_each);
    }

    #[test]
    fn passes_listing_covers_the_registry() {
        let a = parse_args(&sv(&["passes"])).unwrap();
        assert_eq!(a.command, "passes");
        let text = render_passes();
        for &p in crate::passes::registry_ref() {
            assert!(text.contains(p.name()), "missing {}", p.name());
        }
        assert!(text.contains("analysis"));
        assert!(text.contains("transform"));
        // the alias-breaking passes advertise their narrowed contract:
        // CFG analyses survive, the alias summary does not
        let row_of = |name: &str| {
            text.lines()
                .find(|l| l.starts_with(name))
                .unwrap_or_else(|| panic!("no row for {name}"))
                .to_string()
        };
        for narrowed in ["loop-reduce", "bb-vectorize"] {
            let row = row_of(narrowed);
            assert!(row.contains("domtree") && row.contains("loops"), "{row}");
            assert!(!row.contains("alias-summary"), "{row}");
        }
        // CFG restructurers preserve nothing; flag-only passes everything
        assert!(row_of("simplifycfg").contains("(none)"));
        assert!(row_of("cfl-anders-aa").contains("alias-summary"));
    }

    #[test]
    fn store_and_cache_flags_parse_and_validate() {
        // --store rides on the exploration commands …
        for cmd in ["explore", "transfer", "serve"] {
            let a = parse_args(&sv(&[cmd, "--store", "st"])).unwrap();
            assert_eq!(a.cfg.store.as_deref(), Some(std::path::Path::new("st")));
        }
        let a = parse_args(&sv(&["merge", "a.json", "--store", "st"])).unwrap();
        assert_eq!(a.cfg.store.as_deref(), Some(std::path::Path::new("st")));
        // … and nowhere else
        assert!(parse_args(&sv(&["fig2", "--store", "st"])).is_err());
        assert!(parse_args(&sv(&["lower", "GEMM", "--store", "st"])).is_err());
        // serve is meaningless without a store to serve from
        assert!(parse_args(&sv(&["serve"])).is_err());
        // cache needs a store and exactly one known action
        let a = parse_args(&sv(&["cache", "stats", "--store", "st"])).unwrap();
        assert_eq!(a.command, "cache");
        assert_eq!(a.cache_action, "stats");
        assert!(a.max_mb.is_none());
        let a = parse_args(&sv(&["cache", "gc", "--store", "st", "--max-mb", "10"])).unwrap();
        assert_eq!(a.cache_action, "gc");
        assert_eq!(a.max_mb, Some(10));
        assert!(parse_args(&sv(&["cache", "stats"])).is_err(), "no --store");
        assert!(parse_args(&sv(&["cache", "--store", "st"])).is_err(), "no action");
        assert!(parse_args(&sv(&["cache", "shrink", "--store", "st"])).is_err());
        // --max-mb belongs to `cache gc` alone
        assert!(parse_args(&sv(&["cache", "stats", "--store", "st", "--max-mb", "9"])).is_err());
        assert!(parse_args(&sv(&["explore", "--store", "st", "--max-mb", "9"])).is_err());
    }

    #[test]
    fn per_kernel_flag_parses_and_is_validated() {
        let a = parse_args(&sv(&["explore", "--per-kernel"])).unwrap();
        assert!(a.cfg.per_kernel);
        let a = parse_args(&sv(&["explore"])).unwrap();
        assert!(!a.cfg.per_kernel);
        // explore-only, fixed-stream only, unsharded only
        assert!(parse_args(&sv(&["fig2", "--per-kernel"])).is_err());
        assert!(parse_args(&sv(&["transfer", "--per-kernel"])).is_err());
        assert!(
            parse_args(&sv(&["explore", "--strategy", "hillclimb", "--per-kernel"])).is_err()
        );
        assert!(parse_args(&sv(&[
            "explore", "--per-kernel", "--shard", "1/2", "--emit-summary", "x.json",
        ]))
        .is_err());
    }

    #[test]
    fn bench_list_parses_and_is_validated() {
        let a = parse_args(&sv(&["bench", "list"])).unwrap();
        assert_eq!(a.command, "bench");
        assert_eq!(a.bench_action, "list");
        assert!(a.family.is_none());
        let a = parse_args(&sv(&["bench", "list", "--family", "irregular"])).unwrap();
        assert_eq!(a.family.as_deref(), Some("irregular"));
        // the action is mandatory and `list` is the only one
        assert!(parse_args(&sv(&["bench"])).is_err());
        assert!(parse_args(&sv(&["bench", "delete"])).is_err());
        // --family belongs to bench list alone
        assert!(parse_args(&sv(&["explore", "--family", "irregular"])).is_err());
        assert!(parse_args(&sv(&["bench", "list", "--family"])).is_err());
    }

    #[test]
    fn bench_filter_parses_and_is_validated() {
        let a = parse_args(&sv(&["explore", "--bench", "spmv"])).unwrap();
        assert_eq!(a.cfg.only.as_deref(), Some("spmv"));
        let a = parse_args(&sv(&["explore"])).unwrap();
        assert!(a.cfg.only.is_none());
        // unknown names are rejected with the grouped registry listing
        let e = parse_args(&sv(&["explore", "--bench", "NOPE"])).unwrap_err();
        assert!(e.contains("unknown benchmark 'NOPE'"), "{e}");
        assert!(e.contains("irregular"), "{e}");
        // explore-only
        assert!(parse_args(&sv(&["transfer", "--bench", "SPMV"])).is_err());
        assert!(parse_args(&sv(&["fig2", "--bench", "SPMV"])).is_err());
        assert!(parse_args(&sv(&["explore", "--bench"])).is_err());
    }
}
