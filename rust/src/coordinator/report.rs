//! Report rendering: console tables (the same rows/series the paper
//! prints) and JSON dumps under `results/`.

use std::fs;
use std::path::Path;

use super::experiments::{
    fig2_geomeans, winner_alloc_info, Fig2Row, Fig3Matrix, Fig4Scatter, Fig7Result,
    PerKernelReport, ProblemStats, TransferMatrix,
};
use crate::bench_suite::{all_benchmarks, Benchmark, Dims, Variant};
use crate::dse::store::{GcReport, StoreStats, WarmStats, RUN_SCHEMA};
use crate::dse::strategy::{histogram, PermutationStudy};
use crate::dse::{ArenaEntry, ExplorationSummary, Objective};
use crate::sim::target::Target;
use crate::util::{geomean, Json};

pub fn write_json(dir: &Path, name: &str, j: &Json) -> std::io::Result<()> {
    fs::create_dir_all(dir)?;
    fs::write(dir.join(name), j.to_string())
}

// ----------------------------------------------------- explore / merge

/// [`render_explore`] with a strategy-run headline: which strategy ran
/// and how many evaluations each benchmark's summary folds over (the
/// per-benchmark proposal streams of adaptive strategies need not have
/// equal lengths).
pub fn render_explore_strategy(
    strategy: &str,
    summaries: &[ExplorationSummary],
    target: &Target,
) -> String {
    let total: usize = summaries.iter().map(|s| s.evaluations.len()).sum();
    format!(
        "strategy {strategy}: {total} evaluations across {} benchmark(s)\n{}",
        summaries.len(),
        render_explore(summaries, target)
    )
}

/// The `repro explore` / `repro merge` console table: one row per
/// benchmark straight off the [`ExplorationSummary`]s (no -OX probes or
/// minimization — that's the fig2 pipeline). The regs/spills/occ columns
/// are the winning order's allocation on `target`, recomputed at render
/// time via [`winner_alloc_info`] (summary JSON carries no allocation
/// state); `?` marks a winner that no longer compiles.
pub fn render_explore(summaries: &[ExplorationSummary], target: &Target) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:10} {:>12} {:>12} {:>8} | {:>6} {:>6} {:>8} {:>8} {:>6} | {:>4} {:>6} {:>5}  winning sequence\n",
        "bench",
        "baseline",
        "best",
        "speedup",
        "ok",
        "crash",
        "invalid",
        "timeout",
        "hits",
        "regs",
        "spills",
        "occ"
    ));
    for r in summaries {
        let (regs, spills, occ) = match winner_alloc_info(&r.bench, r.best_seq(), target) {
            Some((regs, spills, occ)) => {
                (regs.to_string(), spills.to_string(), format!("{occ:.2}"))
            }
            None => ("?".to_string(), "?".to_string(), "?".to_string()),
        };
        s.push_str(&format!(
            "{:10} {:>12.1} {:>12.1} {:>8.2} | {:>6} {:>6} {:>8} {:>8} {:>6} | {:>4} {:>6} {:>5}  {}\n",
            r.bench,
            r.baseline_time_us,
            r.best_time_us,
            r.best_speedup(),
            r.n_ok,
            r.n_crash,
            r.n_invalid,
            r.n_timeout,
            r.cache_hits,
            regs,
            spills,
            occ,
            match r.best_seq() {
                None => "(baseline — no improving order found)".to_string(),
                Some(seq) =>
                    seq.iter().map(|p| format!("-{p}")).collect::<Vec<_>>().join(" "),
            }
        ));
    }
    let g = geomean(&summaries.iter().map(|r| r.best_speedup()).collect::<Vec<_>>());
    s.push_str(&format!("geomean best-speedup over baseline: {g:.2}x\n"));
    // The objective appendix. `--objective time` emits nothing extra so
    // its console output stays byte-identical to the scalar-era report.
    match summaries.first().map(|r| r.objective).unwrap_or_default() {
        Objective::Time => {}
        obj @ (Objective::Energy | Objective::Size) => {
            let unit = if obj == Objective::Energy { "uJ" } else { " insts" };
            s.push_str(&format!(
                "objective {}: winners minimize the {} component (best/speedup \
                 columns above still report the winners' time)\n",
                obj.name(),
                obj.name()
            ));
            for r in summaries {
                let (b, w) = if obj == Objective::Energy {
                    (r.baseline_energy_uj, r.best_energy_uj)
                } else {
                    (r.baseline_code_size, r.best_code_size)
                };
                s.push_str(&format!(
                    "  {:10} baseline {b:.1}{unit} -> best {w:.1}{unit}\n",
                    r.bench
                ));
            }
        }
        Objective::Pareto => s.push_str(&render_pareto(summaries)),
    }
    s
}

/// The `--objective pareto` appendix: each benchmark's non-dominated
/// (time, energy, size) front, baseline included — the same points
/// `summary.pareto` carries into the JSON dump, in the same canonical
/// order, so console and JSON agree byte-for-byte on the front.
pub fn render_pareto(summaries: &[ExplorationSummary]) -> String {
    let mut s = String::from(
        "Pareto fronts — mutually non-dominated (time, energy, size) points, baseline included:\n",
    );
    for r in summaries {
        s.push_str(&format!("{}: {} point(s)\n", r.bench, r.pareto.len()));
        for p in &r.pareto {
            let label = match p.winner.sequence() {
                None => "(baseline)".to_string(),
                Some(seq) => {
                    seq.iter().map(|q| format!("-{q}")).collect::<Vec<_>>().join(" ")
                }
            };
            s.push_str(&format!(
                "  {:>12.1}us {:>12.1}uJ {:>8.0} insts  {label}\n",
                p.obj.time_us, p.obj.energy_uj, p.obj.code_size
            ));
        }
    }
    s
}

/// The merged summaries as a JSON array (the `repro merge --emit-summary`
/// output; each element round-trips via [`ExplorationSummary::from_json`]).
pub fn summaries_json(summaries: &[ExplorationSummary]) -> Json {
    Json::Arr(summaries.iter().map(|s| s.to_json()).collect())
}

// ----------------------------------------------------- rank (the arena)

/// The `repro rank` console report: every arena entry ranked by geomean
/// best-speedup (ties keep the canonical strategy order), the
/// equal-budget invariant spelled out in the evaluations column, plus a
/// per-benchmark breakdown naming the strategy that led each benchmark.
pub fn render_rank(entries: &[ArenaEntry], target: &Target, budget_per_bench: usize) -> String {
    let nb = entries.first().map(|e| e.summaries.len()).unwrap_or(0);
    let mut s = format!(
        "strategy arena — {} strategies × {nb} benchmark(s), {budget_per_bench} \
         evaluation(s) per benchmark each, on {}:\n",
        entries.len(),
        target.name
    );
    let mut order: Vec<usize> = (0..entries.len()).collect();
    order.sort_by(|&a, &b| {
        entries[b]
            .geomean
            .partial_cmp(&entries[a].geomean)
            .expect("geomeans are finite")
    });
    s.push_str(&format!(
        "{:>4} {:<10} {:>8} {:>12}  note\n",
        "rank", "strategy", "geomean", "evaluations"
    ));
    for (i, &ei) in order.iter().enumerate() {
        let e = &entries[ei];
        let note = match e.strategy {
            "fixed" => "the floor: the paper's blind shared stream",
            "knn" => "the baseline to beat (§4.2 suggestion mechanism)",
            "bandit" => "learned: contextual Thompson sampling",
            "genetic" => "learned: generational GA",
            _ => "",
        };
        s.push_str(&format!(
            "{:>4} {:<10} {:>7.2}x {:>12}  {note}\n",
            i + 1,
            e.strategy,
            e.geomean,
            e.evaluations
        ));
    }
    if nb > 0 {
        s.push_str("per-benchmark best speedups (<- names the leader):\n");
        for bi in 0..nb {
            let mut best = 0usize;
            for (si, e) in entries.iter().enumerate() {
                if e.summaries[bi].best_speedup() > entries[best].summaries[bi].best_speedup() {
                    best = si;
                }
            }
            let row: Vec<String> = entries
                .iter()
                .map(|e| format!("{} {:.2}x", e.strategy, e.summaries[bi].best_speedup()))
                .collect();
            s.push_str(&format!(
                "  {:10} {}  <- {}\n",
                entries[0].summaries[bi].bench,
                row.join(" | "),
                entries[best].strategy
            ));
        }
    }
    s
}

/// The `repro rank` JSON dump (`results/rank.json`), schema
/// `phaseord-rank-v1`: the arena entries in canonical strategy order
/// (`fixed`, `hillclimb`, `knn`, `bandit`, `genetic`), each with its
/// geomean, its (shared) evaluation count, and per-benchmark
/// speedup/winner rows. `null` winners mean the baseline won, matching
/// the fig2 dump's convention.
pub fn rank_json(
    entries: &[ArenaEntry],
    target: &str,
    seed: u64,
    budget_per_bench: usize,
) -> Json {
    Json::Obj(vec![
        ("schema".into(), Json::s("phaseord-rank-v1")),
        ("target".into(), Json::s(target)),
        ("seed".into(), Json::n(seed as f64)),
        ("budget_per_bench".into(), Json::n(budget_per_bench as f64)),
        (
            "strategies".into(),
            Json::Arr(
                entries
                    .iter()
                    .map(|e| {
                        Json::Obj(vec![
                            ("name".into(), Json::s(e.strategy)),
                            ("geomean".into(), Json::n(e.geomean)),
                            ("evaluations".into(), Json::n(e.evaluations as f64)),
                            (
                                "benches".into(),
                                Json::Arr(
                                    e.summaries
                                        .iter()
                                        .map(|s| {
                                            Json::Obj(vec![
                                                ("bench".into(), Json::s(&s.bench)),
                                                ("speedup".into(), Json::n(s.best_speedup())),
                                                ("best_time_us".into(), Json::n(s.best_time_us)),
                                                (
                                                    "winner".into(),
                                                    match s.best_seq() {
                                                        None => Json::Null,
                                                        Some(seq) => Json::Arr(
                                                            seq.iter()
                                                                .map(|p| Json::s(*p))
                                                                .collect(),
                                                        ),
                                                    },
                                                ),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

// ----------------------------------------------------- per-kernel

fn seq_label(seq: Option<&[&'static str]>) -> String {
    match seq {
        None => "(baseline)".to_string(),
        Some(seq) => seq.iter().map(|p| format!("-{p}")).collect::<Vec<_>>().join(" "),
    }
}

/// The `repro explore --per-kernel` appendix: each multi-kernel
/// benchmark's per-kernel winners, reported against the one-shared-order
/// winner over the same candidate set.
pub fn render_per_kernel(reports: &[PerKernelReport]) -> String {
    if reports.is_empty() {
        return "per-kernel: no multi-kernel benchmark in this run\n".to_string();
    }
    let mut s = String::from(
        "per-kernel winners — one order per kernel vs one shared order \
         (modelled time, µs):\n",
    );
    for r in reports {
        s.push_str(&format!(
            "{}: shared {:.1} -> stitched {:.1} ({:.2}x, {})\n",
            r.bench,
            r.shared_time_us,
            r.stitched_time_us,
            r.speedup_vs_shared,
            if r.stitched_valid { "validates" } else { "INVALID" }
        ));
        s.push_str(&format!(
            "  shared winner: {}\n",
            seq_label(r.shared_winner.as_deref())
        ));
        for k in &r.kernels {
            s.push_str(&format!(
                "  {:16} {:>10.1} -> {:>10.1}  {}\n",
                k.kernel,
                k.baseline_time_us,
                k.time_us,
                seq_label(k.winner.as_deref())
            ));
        }
    }
    s
}

/// The `repro explore --per-kernel` JSON dump
/// (`results/per_kernel.json`): one entry per multi-kernel benchmark;
/// `null` winners mean the baseline won (same convention as
/// `best_seq` in the fig2 dump).
pub fn per_kernel_json(reports: &[PerKernelReport]) -> Json {
    fn seq_json(w: Option<&[&'static str]>) -> Json {
        match w {
            None => Json::Null,
            Some(seq) => Json::Arr(seq.iter().map(|p| Json::s(*p)).collect()),
        }
    }
    Json::Obj(vec![(
        "per_kernel".into(),
        Json::Arr(
            reports
                .iter()
                .map(|r| {
                    Json::Obj(vec![
                        ("bench".into(), Json::s(&r.bench)),
                        (
                            "kernels".into(),
                            Json::Arr(
                                r.kernels
                                    .iter()
                                    .map(|k| {
                                        Json::Obj(vec![
                                            ("kernel".into(), Json::s(&k.kernel)),
                                            ("winner".into(), seq_json(k.winner.as_deref())),
                                            ("time_us".into(), Json::n(k.time_us)),
                                            (
                                                "baseline_time_us".into(),
                                                Json::n(k.baseline_time_us),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("shared_winner".into(), seq_json(r.shared_winner.as_deref())),
                        ("shared_time_us".into(), Json::n(r.shared_time_us)),
                        ("stitched_time_us".into(), Json::n(r.stitched_time_us)),
                        ("stitched_valid".into(), Json::Bool(r.stitched_valid)),
                        ("speedup_vs_shared".into(), Json::n(r.speedup_vs_shared)),
                    ])
                })
                .collect(),
        ),
    )])
}

// ----------------------------------------------------- bench list

fn fmt_dims(d: &Dims) -> String {
    if d.tmax > 1 {
        format!("{}x{}x{}t", d.n, d.m, d.tmax)
    } else {
        format!("{}x{}", d.n, d.m)
    }
}

/// The `repro bench list [--family F]` table: every registered
/// benchmark's name, family, dataset dims and kernel count (from the
/// validation-size build — kernel structure is dims-independent).
pub fn render_benches(family: Option<&str>) -> String {
    let benches: Vec<Benchmark> = all_benchmarks()
        .into_iter()
        .filter(|b| family.map_or(true, |f| b.family.eq_ignore_ascii_case(f)))
        .collect();
    if benches.is_empty() {
        let mut fams: Vec<&str> = Vec::new();
        for b in all_benchmarks() {
            if !fams.contains(&b.family) {
                fams.push(b.family);
            }
        }
        return format!(
            "no benchmarks in family '{}'; valid families: {}\n",
            family.unwrap_or(""),
            fams.join(", ")
        );
    }
    let mut s = format!(
        "{:10} {:>16} {:>14} {:>12} {:>7}\n",
        "bench", "family", "full dims", "small dims", "kernels"
    );
    for b in &benches {
        let built = b.build_small(Variant::OpenCl);
        s.push_str(&format!(
            "{:10} {:>16} {:>14} {:>12} {:>7}\n",
            b.name,
            b.family,
            fmt_dims(&b.dims_full),
            fmt_dims(&b.dims_small),
            built.module.kernels.len()
        ));
    }
    s.push_str(&format!("{} benchmark(s)\n", benches.len()));
    s
}

// ----------------------------------------------------- artifact store

/// `DIR/last-run.json`: warm/compile accounting of the latest batch run
/// against an artifact store. The CI warm-store smoke reads it —
/// `compiles` must be 0 on a fully warm second run. Kept out of the
/// summary JSON on purpose: summaries are bit-identical warm vs cold.
pub fn store_run_json(compiles: u64, warm: &WarmStats, cache_totals: (usize, usize)) -> Json {
    Json::Obj(vec![
        ("schema".into(), Json::s(RUN_SCHEMA)),
        ("compiles".into(), Json::n(compiles as f64)),
        ("seq_warm".into(), Json::n(warm.seq_loaded as f64)),
        ("verdict_warm".into(), Json::n(warm.verdict_loaded as f64)),
        ("seq_stale".into(), Json::n(warm.seq_stale as f64)),
        ("verdict_stale".into(), Json::n(warm.verdict_stale as f64)),
        ("seq_memos".into(), Json::n(cache_totals.0 as f64)),
        ("verdicts".into(), Json::n(cache_totals.1 as f64)),
    ])
}

/// The `repro cache stats` console table: per benchmark table, entry
/// counts, bytes, generation, and the epoch fingerprint of each level.
pub fn render_cache_stats(s: &StoreStats, dir: &Path) -> String {
    let mut out = format!(
        "store {} — generation {}, {} table(s), {} bytes\n",
        dir.display(),
        s.generation,
        s.benches.len(),
        s.total_bytes
    );
    out.push_str(&format!(
        "{:10} {:>8} {:>5} {:>6}  {:>18}  per-device verdicts\n",
        "bench", "bytes", "gen", "memos", "seq epoch"
    ));
    for b in &s.benches {
        let verdicts = b
            .verdicts
            .iter()
            .map(|t| format!("{}: {} @ {:#018x}", t.device, t.entries, t.epoch))
            .collect::<Vec<_>>()
            .join(" | ");
        out.push_str(&format!(
            "{:10} {:>8} {:>5} {:>6}  {:#018x}  {}\n",
            b.bench, b.bytes, b.generation, b.seq_entries, b.seq_epoch, verdicts
        ));
    }
    out
}

/// The `repro cache gc` console report.
pub fn render_gc(r: &GcReport, max_bytes: u64) -> String {
    let mut out = format!(
        "gc: {} → {} bytes (budget {}), {} table(s) evicted\n",
        r.bytes_before,
        r.bytes_after,
        max_bytes,
        r.evicted.len()
    );
    for f in &r.evicted {
        out.push_str(&format!("  evicted {f}\n"));
    }
    out
}

// ----------------------------------------------------- §3.1 transfer

/// Per-cell aggregate of a transfer matrix: geomean speedup over the
/// benchmarks whose order validated on the eval target, plus the count
/// of benchmarks whose order did not.
fn transfer_cells(m: &TransferMatrix) -> (Vec<Vec<f64>>, Vec<Vec<usize>>) {
    let nt = m.targets.len();
    let mut g = vec![vec![0.0f64; nt]; nt];
    let mut fails = vec![vec![0usize; nt]; nt];
    for oi in 0..nt {
        for ei in 0..nt {
            let ok: Vec<f64> = m.ratio[oi][ei].iter().copied().filter(|&r| r >= 0.0).collect();
            fails[oi][ei] = m.ratio[oi][ei].len() - ok.len();
            g[oi][ei] = geomean(&ok);
        }
    }
    (g, fails)
}

/// The `repro transfer` console report: the §3.1 cross-device matrix
/// (geomean speedup of each target's specialized orders on every
/// target, relative to the eval target's own baseline) plus the
/// per-benchmark detail rows.
pub fn render_transfer(m: &TransferMatrix) -> String {
    let (g, fails) = transfer_cells(m);
    let nt = m.targets.len();
    let mut s = String::from(
        "§3.1 cross-device transfer — geomean speedup vs each device's own baseline\n\
         (rows: device the orders were specialized on; cols: device they run on)\n\n",
    );
    s.push_str(&format!("{:>24}", "orders from \\ run on"));
    for t in &m.targets {
        s.push_str(&format!(" {:>14}", t));
    }
    s.push('\n');
    for oi in 0..nt {
        s.push_str(&format!("{:>24}", m.targets[oi]));
        for ei in 0..nt {
            // render into a cell first so fail-count suffixes cannot
            // shift the column grid
            let cell = if fails[oi][ei] > 0 {
                format!("{:.2} ({}F)", g[oi][ei], fails[oi][ei])
            } else {
                format!("{:.2}", g[oi][ei])
            };
            s.push_str(&format!(" {cell:>14}"));
        }
        s.push('\n');
    }
    s.push_str("\nper-benchmark detail (owner→eval speedup; FAIL = did not validate):\n");
    for (bi, b) in m.benches.iter().enumerate() {
        s.push_str(&format!("{:10}", b));
        for oi in 0..nt {
            for ei in 0..nt {
                let v = m.ratio[oi][ei][bi];
                let cell = if v < 0.0 {
                    "FAIL".to_string()
                } else {
                    format!("{v:.2}")
                };
                s.push_str(&format!(" {}→{} {:>5}", oi, ei, cell));
            }
        }
        s.push('\n');
    }
    s.push_str(&format!(
        "compiled {} artifact(s) for {} target(s) — the compile count is \
         independent of the target count (compile-once)\n",
        m.compiles, nt
    ));
    s
}

/// The `repro transfer` JSON dump (`results/transfer.json`): the raw
/// ratio tensor plus the per-cell geomean/fail aggregates the CI smoke
/// step checks for non-degeneracy.
pub fn transfer_json(m: &TransferMatrix) -> Json {
    let (g, fails) = transfer_cells(m);
    Json::Obj(vec![
        (
            "targets".into(),
            Json::Arr(m.targets.iter().map(Json::s).collect()),
        ),
        (
            "benches".into(),
            Json::Arr(m.benches.iter().map(Json::s).collect()),
        ),
        (
            "winners".into(),
            Json::Arr(
                m.winners
                    .iter()
                    .map(|per_owner| {
                        Json::Arr(
                            per_owner
                                .iter()
                                .map(|w| match w {
                                    None => Json::Null,
                                    Some(seq) => {
                                        Json::Arr(seq.iter().map(|p| Json::s(*p)).collect())
                                    }
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ),
        (
            "ratio".into(),
            Json::Arr(
                m.ratio
                    .iter()
                    .map(|per_owner| {
                        Json::Arr(
                            per_owner
                                .iter()
                                .map(|row| Json::Arr(row.iter().map(|&v| Json::n(v)).collect()))
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ),
        (
            "geomean".into(),
            Json::Arr(
                g.iter()
                    .map(|row| Json::Arr(row.iter().map(|&v| Json::n(v)).collect()))
                    .collect(),
            ),
        ),
        (
            "fails".into(),
            Json::Arr(
                fails
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|&v| Json::n(v as f64)).collect()))
                    .collect(),
            ),
        ),
        ("compiles".into(), Json::n(m.compiles as f64)),
    ])
}

// ---------------------------------------------------------------- Fig. 2

pub fn render_fig2(rows: &[Fig2Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:10} {:>10} {:>10} {:>10} {:>10} | {:>8} {:>8} {:>8} {:>8}  best sequence\n",
        "bench", "OpenCL", "CUDA", "LLVM", "LLVM-OX", "vs OCL", "vs CUDA", "vs LLVM", "vs -OX"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:10} {:>10.1} {:>10.1} {:>10.1} {:>10.1} | {:>8.2} {:>8.2} {:>8.2} {:>8.2}  {}\n",
            r.bench,
            r.t_opencl_src_us,
            r.t_cuda_us,
            r.t_llvm_us,
            r.t_llvm_ox_us,
            r.speedup_over_opencl(),
            r.speedup_over_cuda(),
            r.speedup_over_llvm(),
            r.speedup_over_llvm_ox(),
            match &r.best_seq {
                None => "(baseline — no improving order found)".to_string(),
                Some(seq) => seq.iter().map(|p| format!("-{p}")).collect::<Vec<_>>().join(" "),
            }
        ));
    }
    let (g_cuda, g_ocl, g_llvm, g_ox) = fig2_geomeans(rows);
    s.push_str(&format!(
        "geomean speedups: over CUDA {g_cuda:.2}x | over OpenCL {g_ocl:.2}x | over LLVM {g_llvm:.2}x | over LLVM -OX {g_ox:.2}x\n",
    ));
    s.push_str("paper (GTX 1070): over CUDA 1.54x (max 5.48) | over OpenCL 1.65x (max 5.70)\n");
    s
}

pub fn fig2_json(rows: &[Fig2Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::Obj(vec![
                    ("bench".into(), Json::s(&r.bench)),
                    ("t_opencl_us".into(), Json::n(r.t_opencl_src_us)),
                    ("t_cuda_us".into(), Json::n(r.t_cuda_us)),
                    ("t_llvm_us".into(), Json::n(r.t_llvm_us)),
                    ("t_llvm_ox_us".into(), Json::n(r.t_llvm_ox_us)),
                    ("best_ox_level".into(), Json::s(&r.best_ox_level)),
                    ("t_phase_us".into(), Json::n(r.t_phase_us)),
                    ("speedup_over_opencl".into(), Json::n(r.speedup_over_opencl())),
                    ("speedup_over_cuda".into(), Json::n(r.speedup_over_cuda())),
                    (
                        // null = baseline won (distinct from [] = the
                        // empty sequence winning)
                        "best_seq".into(),
                        match &r.best_seq {
                            None => Json::Null,
                            Some(seq) => Json::Arr(seq.iter().map(|p| Json::s(*p)).collect()),
                        },
                    ),
                ])
            })
            .collect(),
    )
}

// ---------------------------------------------------------------- Table 1

pub fn render_table1(rows: &[Fig2Row]) -> String {
    let mut s = String::from("Table 1 — best phase orders (minimized):\n");
    for r in rows {
        match &r.best_seq {
            None => s.push_str(&format!(
                "{:10} (baseline — no improving phase order found)\n",
                r.bench
            )),
            Some(seq) => s.push_str(&format!(
                "{:10} {}\n",
                r.bench,
                seq.iter().map(|p| format!("-{p}")).collect::<Vec<_>>().join(" ")
            )),
        }
    }
    s
}

// ---------------------------------------------------------------- Fig. 3

pub fn render_fig3(m: &Fig3Matrix) -> String {
    let mut s = String::from("Fig. 3 — cross-application matrix (rows: sequence owner; cols: benchmark)\n");
    s.push_str(&format!("{:10}", ""));
    for b in &m.benches {
        s.push_str(&format!(" {:>7}", &b[..b.len().min(7)]));
    }
    s.push('\n');
    for (si, owner) in m.benches.iter().enumerate() {
        s.push_str(&format!("{:10}", owner));
        for bi in 0..m.benches.len() {
            let v = m.ratio[si][bi];
            if v < 0.0 {
                s.push_str(&format!(" {:>7}", "FAIL"));
            } else {
                s.push_str(&format!(" {:>7.2}", v));
            }
        }
        s.push('\n');
    }
    s
}

pub fn fig3_json(m: &Fig3Matrix) -> Json {
    Json::Obj(vec![
        (
            "benches".into(),
            Json::Arr(m.benches.iter().map(Json::s).collect()),
        ),
        (
            "ratio".into(),
            Json::Arr(
                m.ratio
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|&v| Json::n(v)).collect()))
                    .collect(),
            ),
        ),
    ])
}

// ---------------------------------------------------------------- Fig. 4

pub fn render_fig4(f: &Fig4Scatter) -> String {
    let mut s = String::from(
        "Fig. 4 — first-100-sequence speedups per benchmark (vs LLVM w/o opt)\n",
    );
    for (name, ys) in &f.series {
        let fails = ys.iter().filter(|&&y| y == 0.0).count();
        let near_base = ys.iter().filter(|&&y| (0.95..=1.05).contains(&y)).count();
        let max = ys.iter().cloned().fold(0.0, f64::max);
        let best = f
            .best
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, b)| *b)
            .unwrap_or(1.0);
        s.push_str(&format!(
            "{:10} fails={:3} near-baseline={:3} max-of-100={:5.2} best-line={:5.2}\n",
            name, fails, near_base, max, best
        ));
    }
    s
}

pub fn fig4_json(f: &Fig4Scatter) -> Json {
    Json::Obj(vec![
        (
            "series".into(),
            Json::Obj(
                f.series
                    .iter()
                    .map(|(n, ys)| {
                        (n.clone(), Json::Arr(ys.iter().map(|&y| Json::n(y)).collect()))
                    })
                    .collect(),
            ),
        ),
        (
            "best".into(),
            Json::Obj(f.best.iter().map(|(n, b)| (n.clone(), Json::n(*b))).collect()),
        ),
    ])
}

// ---------------------------------------------------------------- Fig. 5

pub fn render_fig5(studies: &[PermutationStudy]) -> String {
    let mut s = String::from("Fig. 5 — permutation speedup-over-best distribution\n");
    for st in studies {
        let h = histogram(&st.rel_perf, 10);
        s.push_str(&format!("{:10}", st.bench));
        for (label, count) in &h {
            if *count > 0 {
                s.push_str(&format!(" {label}:{count}"));
            }
        }
        s.push('\n');
    }
    s
}

pub fn fig5_json(studies: &[PermutationStudy]) -> Json {
    Json::Obj(
        studies
            .iter()
            .map(|st| {
                (
                    st.bench.clone(),
                    Json::Arr(st.rel_perf.iter().map(|&v| Json::n(v)).collect()),
                )
            })
            .collect(),
    )
}

// ---------------------------------------------------------------- §3.2

pub fn render_problems(p: &ProblemStats) -> String {
    let mut s = String::from("§3.2 — problematic phase orders (per benchmark)\n");
    s.push_str(&format!(
        "{:10} {:>7} {:>7} {:>9} {:>9}\n",
        "bench", "ok", "crash", "invalid", "timeout"
    ));
    for (b, ok, crash, invalid, timeout) in &p.per_bench {
        s.push_str(&format!(
            "{:10} {:>7} {:>7} {:>9} {:>9}\n",
            b, ok, crash, invalid, timeout
        ));
    }
    let t = p.total_evals.max(1) as f64;
    s.push_str(&format!(
        "TOTAL: ok {:.1}% | crash/no-IR {:.1}% | invalid output {:.1}% | timeout {:.1}%\n",
        100.0 * p.total_ok as f64 / t,
        100.0 * p.total_crash as f64 / t,
        100.0 * p.total_invalid as f64 / t,
        100.0 * p.total_timeout as f64 / t,
    ));
    s.push_str("paper: broken/no report 17% | incorrect output 13% | no optimized IR 3%\n");
    s
}

// ---------------------------------------------------------------- Fig. 7

pub fn render_fig7(f: &Fig7Result) -> String {
    let mut s = String::from("Fig. 7 — geomean speedup vs #sequence evaluations (leave-one-out)\n");
    s.push_str(&format!(
        "{:>4} {:>10} {:>10} {:>10}\n",
        "K", "cosine-kNN", "random", "IterGraph"
    ));
    for (i, k) in f.ks.iter().enumerate() {
        s.push_str(&format!(
            "{:>4} {:>10.3} {:>10.3} {:>10.3}\n",
            k, f.knn[i], f.random[i], f.itergraph[i]
        ));
    }
    s.push_str(&format!(
        "reference (each benchmark's own best order): {:.3}\n",
        f.best_reference
    ));
    s.push_str("paper: kNN K=1 1.49x, K=3 1.56x, K=5 1.59x; all-14 1.60x; best 1.65x\n");
    s
}

pub fn fig7_json(f: &Fig7Result) -> Json {
    Json::Obj(vec![
        ("ks".into(), Json::Arr(f.ks.iter().map(|&k| Json::n(k as f64)).collect())),
        ("knn".into(), Json::Arr(f.knn.iter().map(|&v| Json::n(v)).collect())),
        ("random".into(), Json::Arr(f.random.iter().map(|&v| Json::n(v)).collect())),
        (
            "itergraph".into(),
            Json::Arr(f.itergraph.iter().map(|&v| Json::n(v)).collect()),
        ),
        ("best_reference".into(), Json::n(f.best_reference)),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::experiments::PerKernelKernel;
    use super::*;

    fn row(bench: &str, best_seq: Option<Vec<&'static str>>, t_phase_us: f64) -> Fig2Row {
        Fig2Row {
            bench: bench.into(),
            t_opencl_src_us: 100.0,
            t_llvm_us: 100.0,
            t_llvm_ox_us: 95.0,
            best_ox_level: "-O3".into(),
            t_cuda_us: 90.0,
            t_phase_us,
            best_seq,
            n_ok: 1,
            n_crash: 0,
            n_invalid: 0,
            n_timeout: 0,
            cache_hits: 0,
        }
    }

    #[test]
    fn fig2_render_contains_geomeans() {
        let rows = vec![row("GEMM", Some(vec!["cfl-anders-aa", "licm"]), 50.0)];
        let s = render_fig2(&rows);
        assert!(s.contains("GEMM"));
        assert!(s.contains("geomean"));
        assert!(s.contains("-cfl-anders-aa -licm"));
        let j = fig2_json(&rows).to_string();
        assert!(j.contains("\"speedup_over_opencl\":2"));
    }

    #[test]
    fn transfer_render_and_json_carry_the_matrix() {
        let m = TransferMatrix {
            targets: vec!["nvidia-gp104".into(), "amd-fiji".into()],
            benches: vec!["GEMM".into(), "ATAX".into()],
            winners: vec![
                vec![Some(vec!["licm"]), None],
                vec![None, Some(vec!["gvn", "dse"])],
            ],
            ratio: vec![
                vec![vec![1.8, 1.0], vec![1.2, -1.0]],
                vec![vec![1.0, 1.0], vec![1.3, 1.1]],
            ],
            compiles: 3,
        };
        let s = render_transfer(&m);
        assert!(s.contains("nvidia-gp104") && s.contains("amd-fiji"), "{s}");
        assert!(s.contains("FAIL"), "{s}");
        assert!(s.contains("compiled 3 artifact(s)"), "{s}");
        let j = transfer_json(&m).to_string();
        assert!(j.contains("\"compiles\":3"), "{j}");
        assert!(j.contains("\"geomean\""), "{j}");
        assert!(j.contains("\"fails\""), "{j}");
        // it round-trips through the vendored parser
        let back = Json::parse(&j).unwrap();
        assert_eq!(
            back.get("targets").and_then(|t| t.as_arr()).map(|a| a.len()),
            Some(2)
        );
        // one failed cell → fails[0][1] == 1 and its geomean skips it
        let fails = back.get("fails").and_then(|f| f.as_arr()).unwrap();
        let row0 = fails[0].as_arr().unwrap();
        assert_eq!(row0[1].as_usize(), Some(1));
    }

    #[test]
    fn store_reports_render_and_parse() {
        let warm = WarmStats {
            seq_loaded: 5,
            verdict_loaded: 4,
            seq_stale: 1,
            verdict_stale: 0,
        };
        let j = store_run_json(0, &warm, (6, 4)).to_string();
        let back = Json::parse(&j).unwrap();
        assert_eq!(back.get("compiles").and_then(|c| c.as_usize()), Some(0));
        assert_eq!(back.get("seq_warm").and_then(|c| c.as_usize()), Some(5));
        assert_eq!(back.get("schema").and_then(|s| s.as_str()), Some(RUN_SCHEMA));

        let stats = StoreStats {
            generation: 3,
            total_bytes: 1234,
            benches: vec![crate::dse::store::BenchStats {
                file: "bench-GEMM.json".into(),
                bench: "GEMM".into(),
                bytes: 1234,
                generation: 3,
                seq_entries: 6,
                seq_epoch: 0xAB,
                verdicts: vec![crate::dse::store::TableStats {
                    device: "nvidia-gp104".into(),
                    entries: 4,
                    epoch: 0xCD,
                }],
            }],
        };
        let s = render_cache_stats(&stats, Path::new("/tmp/store"));
        assert!(s.contains("generation 3"), "{s}");
        assert!(s.contains("GEMM") && s.contains("nvidia-gp104: 4"), "{s}");

        let gc = GcReport {
            bytes_before: 2000,
            bytes_after: 900,
            evicted: vec!["bench-ATAX.json".into()],
        };
        let g = render_gc(&gc, 1000);
        assert!(g.contains("evicted bench-ATAX.json"), "{g}");
    }

    fn summary(objective: Objective) -> ExplorationSummary {
        use crate::dse::{ObjVec, ParetoPoint, Winner};
        ExplorationSummary {
            bench: "synthetic".into(),
            baseline_time_us: 100.0,
            baseline_energy_uj: 300.0,
            baseline_code_size: 60.0,
            objective,
            winner: Winner::Sequence(vec!["licm"]),
            best_time_us: 50.0,
            best_energy_uj: 400.0,
            best_code_size: 55.0,
            pareto: vec![
                ParetoPoint {
                    winner: Winner::Sequence(vec!["licm"]),
                    obj: ObjVec { time_us: 50.0, energy_uj: 400.0, code_size: 55.0 },
                },
                ParetoPoint {
                    winner: Winner::Baseline,
                    obj: ObjVec { time_us: 100.0, energy_uj: 300.0, code_size: 60.0 },
                },
            ],
            evaluations: vec![],
            n_ok: 1,
            n_crash: 0,
            n_invalid: 0,
            n_timeout: 0,
            cache_hits: 0,
        }
    }

    #[test]
    fn time_objective_report_has_no_appendix() {
        let s = render_explore(&[summary(Objective::Time)], &Target::gp104());
        assert!(s.ends_with("x\n"), "{s}");
        assert!(!s.contains("objective") && !s.contains("Pareto"), "{s}");
    }

    #[test]
    fn energy_objective_report_appends_the_energy_detail() {
        let s = render_explore(&[summary(Objective::Energy)], &Target::gp104());
        assert!(s.contains("objective energy"), "{s}");
        assert!(s.contains("baseline 300.0uJ -> best 400.0uJ"), "{s}");
    }

    #[test]
    fn pareto_objective_report_renders_every_front_point() {
        let s = render_explore(&[summary(Objective::Pareto)], &Target::gp104());
        assert!(s.contains("Pareto fronts"), "{s}");
        assert!(s.contains("synthetic: 2 point(s)"), "{s}");
        assert!(s.contains("(baseline)"), "{s}");
        assert!(s.contains("-licm"), "{s}");
        assert!(s.contains("50.0us") && s.contains("400.0uJ"), "{s}");
    }

    #[test]
    fn per_kernel_report_renders_and_dumps() {
        let r = PerKernelReport {
            bench: "HISTO".into(),
            kernels: vec![
                PerKernelKernel {
                    kernel: "histo_count".into(),
                    winner: Some(vec!["licm"]),
                    time_us: 8.0,
                    baseline_time_us: 12.0,
                },
                PerKernelKernel {
                    kernel: "histo_scan".into(),
                    winner: None,
                    time_us: 5.0,
                    baseline_time_us: 5.0,
                },
            ],
            shared_winner: Some(vec!["licm"]),
            shared_time_us: 14.0,
            stitched_time_us: 13.0,
            stitched_valid: true,
            speedup_vs_shared: 14.0 / 13.0,
        };
        let s = render_per_kernel(&[r.clone()]);
        assert!(s.contains("HISTO"), "{s}");
        assert!(s.contains("-licm"), "{s}");
        assert!(s.contains("(baseline)"), "{s}");
        assert!(s.contains("validates"), "{s}");
        let j = per_kernel_json(&[r]).to_string();
        assert!(j.contains("\"winner\":null"), "{j}");
        let back = Json::parse(&j).unwrap();
        let arr = back.get("per_kernel").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(
            arr[0].get("stitched_valid").and_then(|v| v.as_bool()),
            Some(true)
        );
        assert!(render_per_kernel(&[]).contains("no multi-kernel"));
    }

    #[test]
    fn rank_report_renders_and_dumps() {
        use crate::dse::Winner;
        let mut won = summary(Objective::Time); // 100us -> 50us = 2.00x
        won.bench = "GEMM".into();
        let mut flat = summary(Objective::Time);
        flat.bench = "GEMM".into();
        flat.winner = Winner::Baseline;
        flat.best_time_us = 100.0;
        let entries = vec![
            ArenaEntry {
                strategy: "fixed",
                geomean: 1.0,
                evaluations: 8,
                summaries: vec![flat],
            },
            ArenaEntry {
                strategy: "bandit",
                geomean: 2.0,
                evaluations: 8,
                summaries: vec![won],
            },
        ];
        let s = render_rank(&entries, &Target::gp104(), 8);
        // bandit outranks fixed despite the canonical entry order
        assert!(s.contains("   1 bandit"), "{s}");
        assert!(s.contains("   2 fixed"), "{s}");
        assert!(s.contains("<- bandit"), "{s}");
        assert!(s.contains("8 evaluation(s) per benchmark"), "{s}");

        let j = rank_json(&entries, "nvidia-gp104", 29, 8).to_string();
        assert!(j.contains("\"winner\":null"), "{j}");
        let back = Json::parse(&j).unwrap();
        assert_eq!(
            back.get("schema").and_then(|v| v.as_str()),
            Some("phaseord-rank-v1")
        );
        assert_eq!(
            back.get("budget_per_bench").and_then(|v| v.as_usize()),
            Some(8)
        );
        let strategies = back.get("strategies").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(strategies.len(), 2);
        // the JSON keeps canonical order — ranking is a render concern
        assert_eq!(
            strategies[0].get("name").and_then(|v| v.as_str()),
            Some("fixed")
        );
        assert_eq!(
            strategies[0].get("evaluations").and_then(|v| v.as_usize()),
            Some(8)
        );
        let benches = strategies[1].get("benches").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(
            benches[0].get("bench").and_then(|v| v.as_str()),
            Some("GEMM")
        );
        assert_eq!(
            benches[0].get("speedup").and_then(|v| v.as_f64()),
            Some(2.0)
        );
    }

    #[test]
    fn bench_list_renders_and_filters_by_family() {
        let all = render_benches(None);
        assert!(all.contains("GEMM") && all.contains("SPMV"), "{all}");
        assert!(all.contains("19 benchmark(s)"), "{all}");
        let irr = render_benches(Some("irregular"));
        assert!(irr.contains("SPMV") && !irr.contains("GEMM"), "{irr}");
        assert!(irr.contains("4 benchmark(s)"), "{irr}");
        let none = render_benches(Some("nope"));
        assert!(none.contains("valid families"), "{none}");
    }

    #[test]
    fn baseline_winner_renders_as_baseline_not_empty_sequence() {
        let rows = vec![row("2DCONV", None, 100.0)];
        let s = render_fig2(&rows);
        assert!(s.contains("(baseline"), "{s}");
        let t = render_table1(&rows);
        assert!(t.contains("(baseline"), "{t}");
        let j = fig2_json(&rows).to_string();
        assert!(j.contains("\"best_seq\":null"), "{j}");
    }
}
