//! One driver per paper experiment (DESIGN.md §6 maps each to its
//! table/figure).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::bench_suite::{all_benchmarks, benchmark_by_name, model_time_us, Benchmark, Variant};
use crate::dse::engine::{self, CacheShards, EvalContext};
use crate::dse::learn::{
    self, ArenaEntry, Bandit, Genetic, DEFAULT_POP, SEED_TAG_BANDIT, SEED_TAG_GENETIC,
};
use crate::dse::shard::{ShardRun, ShardSpec};
use crate::dse::store::{Store, WarmStats};
use crate::dse::strategy::{
    HillClimb, KnnSeeded, Permute, PermutationStudy, SearchStrategy, StrategyKind, DEFAULT_ROUND,
};
use crate::dse::{
    minimize_sequence, permutation_study, ExplorationSummary, Explorer, Objective, SeqGen,
};
use crate::features::{extract_features, rank_neighbors, FeatureVector, IterGraph};
use crate::passes::manager::standard_level;
use crate::runtime::{golden_buffers, GoldenRunner};
use crate::sim::target::Target;
use crate::util::{geomean, Rng};

#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// number of random sequences in the shared DSE stream (paper: 10000)
    pub n_seqs: usize,
    pub seed: u64,
    pub target: Target,
    /// permutations per benchmark for Fig. 5 (paper: up to 1000)
    pub n_perms: usize,
    /// random draws for Fig. 7's random-selection baseline (paper: 1000)
    pub n_random_draws: usize,
    /// evaluation worker threads for the batched engine (0 = all cores).
    /// Results are bit-identical for every value.
    pub jobs: usize,
    /// run the IR verifier after every changing pass of every evaluated
    /// sequence (`--verify-each`) instead of once per sequence — the
    /// test-suite verifier mode, reachable from the CLI
    pub verify_each: bool,
    /// evaluate only this slice of the (benchmark × sequence) grid
    /// (`--shard I/N`); `None` = the whole grid. Only `repro explore`
    /// honours it — shard files are folded back by `repro merge`.
    pub shard: Option<ShardSpec>,
    /// which search strategy `repro explore` drives (`--strategy`);
    /// everything but `Fixed` is adaptive and cannot be sharded
    pub strategy: StrategyKind,
    /// evaluation budget *per benchmark* for adaptive strategies
    /// (`--budget`); 0 = default to `n_seqs`. For `--strategy fixed`
    /// the CLI folds it into `n_seqs` at parse time.
    pub budget: usize,
    /// neighbor count for `--strategy knn` (`--k`, §4.2 uses 1 and 3)
    pub knn_k: usize,
    /// on-disk artifact store directory (`--store DIR`): warm both
    /// cache levels from it at context construction and persist them
    /// back after a run ([`crate::dse::store`]); `None` = cache-cold
    pub store: Option<PathBuf>,
    /// what the winner fold minimizes (`--objective
    /// time|energy|size|pareto`); the evaluation grid and every cache
    /// are objective-independent, so switching it re-folds the same
    /// measurements
    pub objective: Objective,
    /// after a fixed-stream exploration, additionally search a winning
    /// order *per kernel* of every multi-kernel benchmark and report the
    /// stitched program against the one-shared-order winner
    /// (`repro explore --per-kernel`)
    pub per_kernel: bool,
    /// restrict the run to one benchmark (`repro explore --bench NAME`,
    /// case-insensitive); `None` = the whole registry. Validated by the
    /// CLI against [`crate::bench_suite::benchmark_by_name`]
    pub only: Option<String>,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            n_seqs: 1000,
            seed: 0xC0FFEE,
            target: Target::gp104(),
            n_perms: 200,
            n_random_draws: 200,
            jobs: 0,
            verify_each: false,
            shard: None,
            strategy: StrategyKind::Fixed,
            budget: 0,
            knn_k: 3,
            store: None,
            objective: Objective::Time,
            per_kernel: false,
            only: None,
        }
    }
}

/// Shared experiment context: explorers (with their caches), the shared
/// sequence stream, and golden references (AOT artifacts when present,
/// interpreter fallback otherwise). Context construction fans out across
/// the worker pool — golden execution and baseline builds are the
/// per-benchmark fixed cost.
pub struct ExpCtx {
    pub cfg: ExpConfig,
    pub benchmarks: Vec<Benchmark>,
    pub stream: Vec<Vec<&'static str>>,
    explorers: HashMap<String, Explorer>,
    pub used_pjrt_golden: bool,
    /// per-benchmark golden provenance (`"aot-artifacts"` or
    /// `"interpreter"`): the AOT loader falls back per benchmark, and
    /// shard files must record which source judged each benchmark's
    /// verdicts (merge refuses to mix them)
    pub golden_sources: HashMap<String, String>,
    /// open handle on `cfg.store` (both cache levels were warmed from
    /// it at construction)
    store: Option<Store>,
    /// what the store warm-up seeded (zeros when cache-cold)
    pub warm_stats: WarmStats,
    /// `Compiler::compile` calls already spent at construction time —
    /// the baseline [`ExpCtx::run_compiles`] subtracts
    compiles_at_start: u64,
}

impl ExpCtx {
    pub fn new(cfg: ExpConfig) -> ExpCtx {
        let benchmarks = match &cfg.only {
            Some(name) => match crate::bench_suite::benchmark_by_name(name) {
                Some(b) => vec![b],
                None => panic!("{}", crate::bench_suite::unknown_benchmark_error(name)),
            },
            None => all_benchmarks(),
        };
        let stream = SeqGen::stream(cfg.seed, cfg.n_seqs);
        let runner = GoldenRunner::from_env().ok();
        let used_pjrt = AtomicBool::new(false);
        let sources: Mutex<HashMap<String, String>> = Mutex::new(HashMap::new());
        let ctxs = engine::build_contexts_with(&benchmarks, &cfg.target, cfg.jobs, |b| {
            let (golden, src) = match &runner {
                Some(r) if r.has_artifact(b.name) => match golden_buffers(r, b) {
                    Ok(g) => {
                        used_pjrt.store(true, Ordering::Relaxed);
                        (g, "aot-artifacts")
                    }
                    Err(e) => {
                        eprintln!(
                            "warning: {}: AOT golden failed ({e}); interpreter fallback",
                            b.name
                        );
                        (engine::golden_from_interpreter(b), "interpreter")
                    }
                },
                _ => (engine::golden_from_interpreter(b), "interpreter"),
            };
            sources
                .lock()
                .unwrap()
                .insert(b.name.to_string(), src.to_string());
            golden
        });
        let mut explorers = HashMap::new();
        for mut cx in ctxs {
            cx.set_verify_each(cfg.verify_each);
            explorers.insert(cx.name.clone(), Explorer::from_context(cx));
        }
        // warm both cache levels from the on-disk store before any
        // evaluation, so the first lookup of a stored cell hits
        let mut store = None;
        let mut warm_stats = WarmStats::default();
        if let Some(dir) = &cfg.store {
            let st = Store::open(dir);
            for b in &benchmarks {
                warm_stats.add(st.warm(b, explorers[b.name].parts().1));
            }
            eprintln!(
                "store {}: warmed {} sequence memos + {} verdicts ({} stale dropped)",
                dir.display(),
                warm_stats.seq_loaded,
                warm_stats.verdict_loaded,
                warm_stats.seq_stale + warm_stats.verdict_stale
            );
            store = Some(st);
        }
        let mut ctx = ExpCtx {
            cfg,
            benchmarks,
            stream,
            explorers,
            used_pjrt_golden: used_pjrt.into_inner(),
            golden_sources: sources.into_inner().unwrap(),
            store,
            warm_stats,
            compiles_at_start: 0,
        };
        ctx.compiles_at_start = ctx.compile_totals();
        ctx
    }

    pub fn explorer(&mut self, name: &str) -> &mut Explorer {
        self.explorers.get_mut(name).expect("known benchmark")
    }

    /// Immutable view of one benchmark's evaluation context (the staged
    /// compiler + backend pair) — what the transfer driver compiles and
    /// judges artifacts through.
    pub fn eval_context(&self, name: &str) -> &EvalContext {
        self.explorers[name].context()
    }

    /// The engine's view of every benchmark: `(EvalContext, CacheShards)`
    /// pairs in benchmark order — what `engine::run` / `explore_pairs`
    /// consume.
    pub fn parts(&self) -> Vec<(&EvalContext, &CacheShards)> {
        self.benchmarks
            .iter()
            .map(|b| self.explorers[b.name].parts())
            .collect()
    }

    /// Batched parallel exploration of the shared stream across all
    /// benchmarks (the entry point every figure driver goes through) —
    /// semantically the
    /// [`FixedStream`](crate::dse::strategy::FixedStream) strategy
    /// through `engine::run`
    /// (golden-tested bit-identical), implemented via the zero-copy
    /// grid walk so the shared stream is not duplicated per benchmark
    /// at `--full` scale. Seeds the per-benchmark caches, so the
    /// follow-up figure-specific evaluations mostly hit.
    pub fn explore_all(&self) -> Vec<ExplorationSummary> {
        engine::explore_pairs_obj(&self.parts(), &self.stream, self.cfg.jobs, self.cfg.objective)
    }

    /// Drive any [`SearchStrategy`] over all benchmarks, capped at
    /// `budget` total evaluations (`usize::MAX` = let the strategy
    /// exhaust itself).
    pub fn run_strategy(
        &self,
        strategy: &mut dyn SearchStrategy,
        budget: usize,
    ) -> Vec<ExplorationSummary> {
        engine::run_obj(strategy, &self.parts(), budget, self.cfg.jobs, self.cfg.objective)
    }

    /// The per-benchmark evaluation budget adaptive strategies work
    /// with: `--budget`, defaulting to the stream length.
    pub fn budget_per_bench(&self) -> usize {
        if self.cfg.budget == 0 {
            self.cfg.n_seqs
        } else {
            self.cfg.budget
        }
    }

    /// `repro explore --strategy …` end to end: construct the configured
    /// strategy and run it. The adaptive strategies that need reference
    /// winners (`permute` seeds permutations of each benchmark's best
    /// order; `knn` seeds from the winners of the nearest reference
    /// benchmarks, §4.2) first run the shared-stream exploration to
    /// obtain them — the same protocol the paper uses, and every phase
    /// is deterministic at any `--jobs` level.
    pub fn explore_strategy(&self) -> Vec<ExplorationSummary> {
        let nb = self.benchmarks.len();
        let per_bench = self.budget_per_bench();
        match self.cfg.strategy {
            StrategyKind::Fixed => self.explore_all(),
            StrategyKind::HillClimb => {
                let mut s = HillClimb::new(nb, self.cfg.seed ^ 0xC11B, DEFAULT_ROUND);
                s.set_objective(self.cfg.objective);
                self.run_strategy(&mut s, per_bench * nb)
            }
            StrategyKind::Permute => {
                let bases = winning_sequences(&self.explore_all());
                let mut s = Permute::new(bases, per_bench.saturating_sub(1), self.cfg.seed ^ 0x515);
                self.run_strategy(&mut s, per_bench * nb)
            }
            StrategyKind::Knn => {
                let winners = winning_sequences(&self.explore_all());
                let feats = self.feature_vectors();
                let mut s = KnnSeeded::new(
                    &feats,
                    &winners,
                    self.cfg.knn_k,
                    self.cfg.seed ^ 0x4A2,
                    DEFAULT_ROUND,
                );
                s.set_objective(self.cfg.objective);
                self.run_strategy(&mut s, per_bench * nb)
            }
            StrategyKind::Bandit => {
                let feats = self.feature_vectors();
                let mut s = Bandit::new(&feats, self.cfg.seed ^ SEED_TAG_BANDIT, DEFAULT_ROUND);
                s.set_objective(self.cfg.objective);
                self.run_strategy(&mut s, per_bench * nb)
            }
            StrategyKind::Genetic => {
                let mut s = Genetic::new(nb, self.cfg.seed ^ SEED_TAG_GENETIC, DEFAULT_POP);
                s.set_objective(self.cfg.objective);
                self.run_strategy(&mut s, per_bench * nb)
            }
        }
    }

    /// `repro rank` end to end: the equal-budget strategy arena
    /// ([`crate::dse::learn::rank_strategies`]) over this context's
    /// benchmarks — every shipped strategy at `budget_per_bench()`
    /// evaluations per benchmark, fresh caches per strategy, reported
    /// in canonical order.
    pub fn rank_strategies(&self) -> Vec<ArenaEntry> {
        let parts = self.parts();
        let ctxs: Vec<&EvalContext> = parts.iter().map(|&(c, _)| c).collect();
        let feats = self.feature_vectors();
        learn::rank_strategies(
            &ctxs,
            &feats,
            self.budget_per_bench(),
            self.cfg.knn_k,
            self.cfg.seed,
            self.cfg.jobs,
            self.cfg.objective,
        )
    }

    /// MILEPOST-style feature vectors of every benchmark's unoptimized
    /// OpenCL build, in benchmark order (§4.1 — shared by fig7 and the
    /// kNN strategy).
    pub fn feature_vectors(&self) -> Vec<(String, FeatureVector)> {
        self.benchmarks
            .iter()
            .map(|b| {
                let built = b.build_small(Variant::OpenCl);
                (b.name.to_string(), extract_features(&built.module))
            })
            .collect()
    }

    /// Evaluate this process's shard of the grid (`cfg.shard`, defaulting
    /// to the whole grid) and package the raw evaluation streams for
    /// `--emit-summary` / `repro merge`. Does **not** fold: cache
    /// attribution is replayed over the combined stream at merge time.
    pub fn explore_shard(&self) -> ShardRun {
        let spec = self.cfg.shard.unwrap_or_else(ShardSpec::full);
        let parts = self.parts();
        // per-benchmark provenance: the AOT loader falls back to the
        // interpreter per benchmark, and merge refuses mixed sources
        let goldens: Vec<&str> = self
            .benchmarks
            .iter()
            .map(|b| self.golden_sources[b.name].as_str())
            .collect();
        ShardRun::execute(
            &parts,
            &self.stream,
            spec,
            self.cfg.jobs,
            self.cfg.target.name,
            self.cfg.seed,
            self.cfg.verify_each,
            &goldens,
        )
    }

    /// Package already-computed summaries as the mergeable `1/1` shard
    /// file (the unsharded `--emit-summary` path) — no re-evaluation.
    pub fn package_summaries(&self, summaries: &[ExplorationSummary]) -> ShardRun {
        let goldens: Vec<&str> = self
            .benchmarks
            .iter()
            .map(|b| self.golden_sources[b.name].as_str())
            .collect();
        ShardRun::from_summaries(
            &self.stream,
            summaries,
            self.cfg.target.name,
            self.cfg.seed,
            self.cfg.verify_each,
            &goldens,
        )
    }

    /// Total live-cache occupancy across all benchmarks: (sequence-memo
    /// entries, vPTX-verdict entries). Surfaced by `repro explore` after
    /// a run; reads are post-pool snapshots (see [`CacheShards::len`]).
    pub fn cache_totals(&self) -> (usize, usize) {
        self.benchmarks.iter().fold((0, 0), |(seq, ptx), b| {
            let (s, p) = self.explorers[b.name].parts().1.len();
            (seq + s, ptx + p)
        })
    }

    /// Total `Compiler::compile` calls across all benchmark contexts
    /// (the compile-once counter, post-pool snapshot).
    pub fn compile_totals(&self) -> u64 {
        self.benchmarks
            .iter()
            .map(|b| self.eval_context(b.name).compiler().compile_count())
            .sum()
    }

    /// Compile calls spent since construction — what exploration
    /// actually paid. Zero on a fully warm store, the acceptance
    /// invariant the CI warm-store smoke asserts.
    pub fn run_compiles(&self) -> u64 {
        self.compile_totals() - self.compiles_at_start
    }

    pub fn has_store(&self) -> bool {
        self.store.is_some()
    }

    /// Persist every benchmark's caches back into the store under one
    /// fresh generation, plus `last-run.json` with this run's
    /// warm/compile accounting (the summaries themselves must stay
    /// bit-identical warm vs cold, so the stats live here instead).
    pub fn persist_store(&self) -> std::io::Result<()> {
        let Some(st) = &self.store else {
            return Ok(());
        };
        let generation = st.bump_generation()?;
        for b in &self.benchmarks {
            st.persist(b, self.explorers[b.name].parts().1, generation)?;
        }
        let run = super::report::store_run_json(
            self.run_compiles(),
            &self.warm_stats,
            self.cache_totals(),
        );
        crate::util::emit_json(&st.dir().join("last-run.json"), &run)?;
        eprintln!(
            "store: persisted generation {generation} ({} benchmark tables) to {}",
            self.benchmarks.len(),
            st.dir().display()
        );
        Ok(())
    }
}

/// Allocation summary of one benchmark's winning order on `target`:
/// `(max regs/thread, total spill slots, min occupancy)` across the
/// full build's kernels — the regs/spills/occupancy columns the
/// `repro explore` / `repro merge` winner tables render. Recomputed at
/// render time from the order (allocation is a pure function of the
/// lowered code and the target), so summary/shard JSON schemas carry no
/// allocation state. `None` when the benchmark is unknown or the order
/// no longer compiles.
pub fn winner_alloc_info(
    bench: &str,
    seq: Option<&[&'static str]>,
    target: &Target,
) -> Option<(u32, u32, f64)> {
    let b = benchmark_by_name(bench)?;
    let compiler = crate::dse::Compiler::from_builds(
        b.build_small(Variant::OpenCl),
        b.build_full(Variant::OpenCl),
    );
    let ck = compiler.compile(seq.unwrap_or(&[])).ok()?;
    let mut regs = 0u32;
    let mut spills = 0u32;
    let mut occ = 1.0f64;
    for lk in &ck.lowered {
        let ak = lk.allocated(target);
        regs = regs.max(ak.stats.regs_per_thread);
        spills += ak.stats.spill_slots;
        occ = occ.min(crate::sim::cost::occupancy(ak.stats.regs_per_thread, target));
    }
    Some((regs, spills, occ))
}

/// Each summary's winning sequence (`None` = baseline won) — the
/// reference pool the permute/knn strategies seed from.
pub fn winning_sequences(summaries: &[ExplorationSummary]) -> Vec<Option<Vec<&'static str>>> {
    summaries
        .iter()
        .map(|s| s.winner.sequence().map(|q| q.to_vec()))
        .collect()
}

// ------------------------------------------------------------ per-kernel

/// One kernel's row in a [`PerKernelReport`]: the order that minimizes
/// *this kernel's* modelled time across the validated stream.
#[derive(Debug, Clone)]
pub struct PerKernelKernel {
    /// kernel name (from the full build's module)
    pub kernel: String,
    /// winning order for this kernel alone (`None` = baseline)
    pub winner: Option<Vec<&'static str>>,
    /// this kernel's modelled time under its own winner, µs
    pub time_us: f64,
    /// this kernel's modelled time under the baseline (empty order), µs
    pub baseline_time_us: f64,
}

/// `repro explore --per-kernel` outcome for one multi-kernel benchmark:
/// per-kernel winners, the one-shared-order winner they are reported
/// against, and the stitched program's validity.
#[derive(Debug, Clone)]
pub struct PerKernelReport {
    pub bench: String,
    /// one row per kernel, in module order
    pub kernels: Vec<PerKernelKernel>,
    /// the single order minimizing the *total* modelled time over the
    /// same candidate set (`None` = baseline)
    pub shared_winner: Option<Vec<&'static str>>,
    /// total modelled time under the shared winner, µs
    pub shared_time_us: f64,
    /// total modelled time of the stitched program (Σ of per-kernel
    /// minima) — ≤ `shared_time_us` by construction, µs
    pub stitched_time_us: f64,
    /// whether the stitched validation build still matches the golden
    /// reference (kernels optimized under different orders can interact
    /// through shared buffers; stitching must re-validate)
    pub stitched_valid: bool,
    /// `shared_time_us / stitched_time_us`
    pub speedup_vs_shared: f64,
}

/// Search a winning order **per kernel** of every multi-kernel
/// benchmark (MM2/MM3's chained matmuls, HISTO's histogram→scan, BFS's
/// frontier ping-pong) and report it against the one-shared-order
/// winner.
///
/// Candidates are the baseline (empty order) plus every stream sequence
/// whose whole-program evaluation validated on this context's backend,
/// deduplicated by sequence key — so the per-kernel search never crowns
/// an order the normal pipeline rejected. Per-kernel times come from
/// the cost-model pricing path ([`crate::sim::cost::LoweredKernel`]
/// estimates with the baseline trip-count fallback) on **every**
/// backend, including the host: the shared winner is re-derived from
/// the same per-kernel sums, so the comparison is apples-to-apples and
/// `stitched_time_us ≤ shared_time_us` holds by construction.
///
/// The stitched program splices each kernel's winning validation-size
/// kernel into one module and re-validates it against the golden
/// reference through the interpreter under the context's step budget.
/// Requires the summaries of a fixed-stream, unsharded run (evaluation
/// `i` must correspond to `ctx.stream[i]`) — the CLI enforces this.
pub fn per_kernel_reports(
    ctx: &ExpCtx,
    summaries: &[ExplorationSummary],
) -> Vec<PerKernelReport> {
    use crate::bench_suite::{execute, init_buffers, outputs_match};
    use crate::dse::evaluator::VALIDATION_TOLERANCE;

    let mut reports = Vec::new();
    for b in &ctx.benchmarks {
        let Some(summary) = summaries.iter().find(|s| s.bench == b.name) else {
            continue;
        };
        let cx = ctx.eval_context(b.name);
        let full = cx.compiler().full_build();
        let nk = full.module.kernels.len();
        if nk < 2 {
            continue;
        }
        let target = cx.target();
        let trips = crate::bench_suite::baseline_max_trips(full, target);

        // candidate orders: baseline first (index 0 wins ties), then the
        // validated stream sequences, deduplicated by sequence key
        let empty: Vec<&'static str> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        seen.insert(EvalContext::seq_key(&empty));
        let mut cands: Vec<&[&'static str]> = vec![&empty];
        for (si, seq) in ctx.stream.iter().enumerate() {
            let validated = summary
                .evaluations
                .get(si)
                .map_or(false, |e| e.status.is_ok());
            if validated && seen.insert(EvalContext::seq_key(seq)) {
                cands.push(seq);
            }
        }

        // phase 1: price every candidate per kernel (compile-only; the
        // artifact is dropped so N candidates never coexist in memory)
        let priced: Vec<Option<Vec<f64>>> = cands
            .iter()
            .map(|seq| {
                let ck = cx.compile(seq).ok()?;
                Some(
                    ck.lowered
                        .iter()
                        .zip(&ck.full.kernels)
                        .enumerate()
                        .map(|(ki, (lk, info))| {
                            let unknown = trips
                                .get(ki)
                                .copied()
                                .unwrap_or(crate::sim::cost::UNKNOWN_TRIPS_DEFAULT);
                            lk.estimate(info.grid, target, unknown).time_us
                                * info.repeat as f64
                                * ck.full.seq_repeat as f64
                        })
                        .collect(),
                )
            })
            .collect();
        let Some(base_times) = priced[0].clone() else {
            continue; // baseline must compile; defensive
        };

        // phase 2: fold winners — shared = argmin of the total, kernel k
        // = argmin of component k (strict <, so earlier candidates win
        // ties and the baseline wins an all-tie)
        let mut shared_i = 0usize;
        let mut shared_total = f64::INFINITY;
        let mut kernel_i = vec![0usize; nk];
        let mut kernel_t = vec![f64::INFINITY; nk];
        for (ci, times) in priced.iter().enumerate() {
            let Some(times) = times else { continue };
            let total: f64 = times.iter().sum();
            if total < shared_total {
                shared_total = total;
                shared_i = ci;
            }
            for k in 0..nk {
                if times[k] < kernel_t[k] {
                    kernel_t[k] = times[k];
                    kernel_i[k] = ci;
                }
            }
        }

        // phase 3: stitch — recompile only the distinct winners and
        // splice each kernel's winning validation-size kernel into one
        // module, then re-validate against the golden reference
        let mut stitched = cx.compiler().small_build().clone();
        let mut by_cand: HashMap<usize, Vec<usize>> = HashMap::new();
        for (k, &ci) in kernel_i.iter().enumerate() {
            by_cand.entry(ci).or_default().push(k);
        }
        let mut stitch_ok = true;
        for (&ci, ks) in &by_cand {
            match cx.compile(cands[ci]) {
                Ok(ck) => {
                    for &k in ks {
                        stitched.module.kernels[k] = ck.small.module.kernels[k].clone();
                    }
                }
                Err(_) => stitch_ok = false,
            }
        }
        let stitched_valid = stitch_ok && {
            let mut bufs = init_buffers(&stitched);
            match execute(&stitched, &mut bufs, cx.step_limit()) {
                Ok(_) => outputs_match(&stitched, &bufs, cx.golden(), VALIDATION_TOLERANCE),
                Err(_) => false,
            }
        };

        let winner_of = |ci: usize| -> Option<Vec<&'static str>> {
            if ci == 0 {
                None
            } else {
                Some(cands[ci].to_vec())
            }
        };
        let stitched_time_us: f64 = kernel_t.iter().sum();
        let kernels = (0..nk)
            .map(|k| PerKernelKernel {
                kernel: full.module.kernels[k].name.clone(),
                winner: winner_of(kernel_i[k]),
                time_us: kernel_t[k],
                baseline_time_us: base_times[k],
            })
            .collect();
        reports.push(PerKernelReport {
            bench: b.name.to_string(),
            kernels,
            shared_winner: winner_of(shared_i),
            shared_time_us: shared_total,
            stitched_time_us,
            stitched_valid,
            speedup_vs_shared: if stitched_time_us > 0.0 {
                shared_total / stitched_time_us
            } else {
                1.0
            },
        });
    }
    reports
}

// ------------------------------------------------------------ §3.1 transfer

/// The `repro transfer` outcome: each registered target's specialized
/// winning orders, cross-evaluated on every registered target.
#[derive(Debug, Clone)]
pub struct TransferMatrix {
    /// registered target names, in [`Target::all`] order (owner order ==
    /// eval order)
    pub targets: Vec<String>,
    pub benches: Vec<String>,
    /// `winners[oi][bi]`: the order target `oi`'s exploration found for
    /// benchmark `bi` (`None` = baseline won; it cross-applies as the
    /// empty sequence, the paper's `-O0` fallback)
    pub winners: Vec<Vec<Option<Vec<&'static str>>>>,
    /// `ratio[oi][ei][bi]`: speedup of owner `oi`'s winner for benchmark
    /// `bi` on eval target `ei`, relative to `ei`'s *own baseline*
    /// (`-1.0` = the order failed validation there). The diagonal
    /// `oi == ei` reproduces each exploration's own best speedups.
    pub ratio: Vec<Vec<Vec<f64>>>,
    /// compile calls spent on the cross-evaluation: exactly one per
    /// distinct `(benchmark, winning order)` artifact, **independent of
    /// the target count** — the compile-once contract, asserted in
    /// `rust/tests/evaluator.rs`.
    pub compiles: u64,
}

/// Run the §3.1 cross-device transfer experiment: one fixed-stream
/// exploration per registered target (each under its own cost tables),
/// then compile every distinct winning order **once** —
/// [`Compiler`](crate::dse::Compiler) is target-independent — and
/// validate + price the artifact under every target's backend.
/// `cfg.target` is ignored: the experiment always spans [`Target::all`].
pub fn transfer_matrix(cfg: &ExpConfig) -> TransferMatrix {
    let targets = Target::all();
    let mut ctxs: Vec<ExpCtx> = Vec::with_capacity(targets.len());
    for t in &targets {
        let mut c = cfg.clone();
        c.target = t.clone();
        ctxs.push(ExpCtx::new(c));
    }
    let benches: Vec<String> = ctxs[0]
        .benchmarks
        .iter()
        .map(|b| b.name.to_string())
        .collect();
    let mut winners: Vec<Vec<Option<Vec<&'static str>>>> = Vec::with_capacity(targets.len());
    for (ti, ctx) in ctxs.iter().enumerate() {
        eprintln!(
            "transfer: exploring {} sequences × {} benchmarks on {} ({}/{}) …",
            ctx.cfg.n_seqs,
            benches.len(),
            targets[ti].name,
            ti + 1,
            targets.len()
        );
        winners.push(winning_sequences(&ctx.explore_all()));
    }
    // Cross-evaluation. Artifacts come from ctxs[0]'s compilers (every
    // target's compiler holds identical builds — compilation is
    // target-independent), deduplicated per (benchmark, order) so the
    // compile count cannot depend on how many targets are evaluated.
    let count_compiles = |c: &ExpCtx| -> u64 {
        c.benchmarks
            .iter()
            .map(|b| c.eval_context(b.name).compiler().compile_count())
            .sum()
    };
    let compiles_before = count_compiles(&ctxs[0]);
    let nt = targets.len();
    let nb = benches.len();
    let mut ratio = vec![vec![vec![0.0f64; nb]; nt]; nt];
    for (bi, bname) in benches.iter().enumerate() {
        let compile_cx = ctxs[0].eval_context(bname);
        // memoized per distinct order: compile once AND judge once per
        // eval target — owners sharing a winner (common: the baseline
        // fallback) reuse the whole judged row, not just the artifact
        let mut judged: HashMap<u64, Vec<f64>> = HashMap::new();
        for oi in 0..nt {
            let seq: &[&'static str] = winners[oi][bi].as_deref().unwrap_or(&[]);
            let key = EvalContext::seq_key(seq);
            let row = judged.entry(key).or_insert_with(|| {
                match compile_cx.compile(seq) {
                    // a winner that does not even compile cannot transfer
                    Err(_) => vec![-1.0; nt],
                    Ok(ck) => (0..nt)
                        .map(|ei| {
                            let cx = ctxs[ei].eval_context(bname);
                            let ev = cx.evaluate_artifact(&ck);
                            if ev.status.is_ok() {
                                cx.baseline_time_us / ev.time_us
                            } else {
                                -1.0
                            }
                        })
                        .collect(),
                }
            });
            for ei in 0..nt {
                ratio[oi][ei][bi] = row[ei];
            }
        }
    }
    let compiles = count_compiles(&ctxs[0]) - compiles_before;
    // persist each target's exploration caches (sequence memos are
    // shared per benchmark file; each context contributes its own
    // device's verdict column, merged under matching epochs)
    for ctx in &ctxs {
        if let Err(e) = ctx.persist_store() {
            eprintln!("warning: store persist failed: {e}");
        }
    }
    TransferMatrix {
        targets: targets.iter().map(|t| t.name.to_string()).collect(),
        benches,
        winners,
        ratio,
        compiles,
    }
}

// ------------------------------------------------------------ Fig. 2 + Table 1

#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub bench: String,
    pub t_opencl_src_us: f64,
    pub t_llvm_us: f64,
    pub t_llvm_ox_us: f64,
    pub best_ox_level: String,
    pub t_cuda_us: f64,
    pub t_phase_us: f64,
    /// minimized winning phase order; `None` when no sequence beat the
    /// baseline (the 2DCONV/3DCONV/FDTD-2D case in the paper's Table 1)
    pub best_seq: Option<Vec<&'static str>>,
    pub n_ok: usize,
    pub n_crash: usize,
    pub n_invalid: usize,
    pub n_timeout: usize,
    pub cache_hits: usize,
}

impl Fig2Row {
    pub fn speedup_over_opencl(&self) -> f64 {
        self.t_opencl_src_us / self.t_phase_us
    }
    pub fn speedup_over_cuda(&self) -> f64 {
        self.t_cuda_us / self.t_phase_us
    }
    pub fn speedup_over_llvm(&self) -> f64 {
        self.t_llvm_us / self.t_phase_us
    }
    pub fn speedup_over_llvm_ox(&self) -> f64 {
        self.t_llvm_ox_us / self.t_phase_us
    }
}

/// Fig. 2: phase-ordering speedups over all four baselines, plus Table 1
/// (minimized best sequences). One batched DSE over the shared stream —
/// all (benchmark × sequence) items go through the parallel engine —
/// followed by per-benchmark -OX probes and minimization.
pub fn fig2_table1(ctx: &mut ExpCtx) -> Vec<Fig2Row> {
    let summaries = ctx.explore_all();
    let mut rows = Vec::new();
    // ctx.benchmarks is the one authoritative list (summaries are in
    // its order); copied out so `ctx.explorer(..)` can borrow mutably
    let benches: Vec<Benchmark> = ctx.benchmarks.clone();
    for (b, summary) in benches.iter().zip(summaries) {
        assert_eq!(b.name, summary.bench, "benchmark/summary order mismatch");
        let t_cuda = model_time_us(&b.build_full(Variant::Cuda), &ctx.cfg.target);
        // offline LLVM w/o opt == the de-facto from-source flow (§3.1:
        // "no significant performance difference"); both are the
        // unoptimized OpenCL build in this substrate.
        let t_ocl = model_time_us(&b.build_full(Variant::OpenCl), &ctx.cfg.target);
        let t_llvm = t_ocl;
        // best standard level, validated
        let mut t_ox = t_llvm;
        let mut best_level = "-O0".to_string();
        {
            let ex = ctx.explorer(b.name);
            for lvl in ["-O1", "-O2", "-O3", "-Os"] {
                let seq = standard_level(lvl).expect("known optimization level");
                let ev = ex.evaluate(&seq);
                if ev.status.is_ok() && ev.time_us < t_ox {
                    t_ox = ev.time_us;
                    best_level = lvl.to_string();
                }
            }
        }
        let ex = ctx.explorer(b.name);
        let (best_seq, t_phase) = match summary.winner.sequence() {
            None => (None, summary.baseline_time_us),
            Some(seq) => {
                let (min_seq, t) = minimize_sequence(ex, seq);
                (Some(min_seq), t)
            }
        };
        rows.push(Fig2Row {
            bench: b.name.to_string(),
            t_opencl_src_us: t_ocl,
            t_llvm_us: t_llvm,
            t_llvm_ox_us: t_ox,
            best_ox_level: best_level,
            t_cuda_us: t_cuda,
            t_phase_us: t_phase.min(summary.baseline_time_us),
            best_seq,
            n_ok: summary.n_ok,
            n_crash: summary.n_crash,
            n_invalid: summary.n_invalid,
            n_timeout: summary.n_timeout,
            cache_hits: summary.cache_hits,
        });
    }
    rows
}

pub fn fig2_geomeans(rows: &[Fig2Row]) -> (f64, f64, f64, f64) {
    (
        geomean(&rows.iter().map(|r| r.speedup_over_cuda()).collect::<Vec<_>>()),
        geomean(&rows.iter().map(|r| r.speedup_over_opencl()).collect::<Vec<_>>()),
        geomean(&rows.iter().map(|r| r.speedup_over_llvm()).collect::<Vec<_>>()),
        geomean(&rows.iter().map(|r| r.speedup_over_llvm_ox()).collect::<Vec<_>>()),
    )
}

// ------------------------------------------------------------ Fig. 3

#[derive(Debug, Clone)]
pub struct Fig3Matrix {
    pub benches: Vec<String>,
    /// `ratio[seq_owner][bench]`: perf of owner's sequence on bench,
    /// relative to bench's own best. -1 encodes validation failure.
    pub ratio: Vec<Vec<f64>>,
}

/// Fig. 3: cross-application of each benchmark's best sequence.
pub fn fig3_cross(ctx: &mut ExpCtx, table1: &[Fig2Row]) -> Fig3Matrix {
    let names: Vec<String> = table1.iter().map(|r| r.bench.clone()).collect();
    let mut ratio = vec![vec![0.0; names.len()]; names.len()];
    for (si, owner) in table1.iter().enumerate() {
        // a baseline "winner" cross-applies as the empty sequence (-O0)
        let owner_seq: &[&'static str] = owner.best_seq.as_deref().unwrap_or(&[]);
        for (bi, bench) in table1.iter().enumerate() {
            let ex = ctx.explorer(&bench.bench);
            let ev = ex.evaluate(owner_seq);
            ratio[si][bi] = if ev.status.is_ok() {
                (bench.t_phase_us / ev.time_us).min(1.0)
            } else {
                -1.0
            };
        }
    }
    Fig3Matrix {
        benches: names,
        ratio,
    }
}

// ------------------------------------------------------------ Fig. 4

#[derive(Debug, Clone)]
pub struct Fig4Scatter {
    /// per benchmark: (name, per-sequence speedup over LLVM-no-opt;
    /// 0 = failed), first 100 sequences of the shared stream
    pub series: Vec<(String, Vec<f64>)>,
    pub best: Vec<(String, f64)>,
}

pub fn fig4_scatter(ctx: &mut ExpCtx, table1: &[Fig2Row]) -> Fig4Scatter {
    let first100: Vec<Vec<&'static str>> = ctx.stream.iter().take(100).cloned().collect();
    let mut series = Vec::new();
    let mut best = Vec::new();
    for row in table1 {
        let ex = ctx.explorer(&row.bench);
        let base = ex.baseline_time_us;
        let mut ys = Vec::with_capacity(first100.len());
        for s in &first100 {
            let ev = ex.evaluate(s);
            ys.push(if ev.status.is_ok() { base / ev.time_us } else { 0.0 });
        }
        series.push((row.bench.clone(), ys));
        best.push((row.bench.clone(), base / row.t_phase_us));
    }
    Fig4Scatter { series, best }
}

// ------------------------------------------------------------ Fig. 5

pub fn fig5_permutations(ctx: &mut ExpCtx, table1: &[Fig2Row]) -> Vec<PermutationStudy> {
    let mut out = Vec::new();
    for row in table1 {
        // paper: 2DCONV/3DCONV/FDTD-2D excluded (no improving order)
        let Some(best_seq) = &row.best_seq else { continue };
        if row.speedup_over_llvm() < 1.01 {
            continue;
        }
        let n = ctx.cfg.n_perms;
        let seed = ctx.cfg.seed ^ 0x515;
        let ex = ctx.explorer(&row.bench);
        out.push(permutation_study(ex, best_seq, n, seed));
    }
    out
}

// ------------------------------------------------------------ Fig. 6

/// Fig. 6: the PTX load patterns — CUDA-style (strength-reduced) vs
/// OpenCL-style (naive 5-instruction chain) for 2DCONV.
pub fn fig6_load_patterns() -> (String, String) {
    let b = crate::bench_suite::benchmark_by_name("2DCONV").unwrap();
    let ocl = b.build_small(Variant::OpenCl);
    let cuda = b.build_small(Variant::Cuda);
    let p_ocl = crate::codegen::emit(&ocl.module.kernels[0], &ocl.module);
    let p_cuda = crate::codegen::emit(&cuda.module.kernels[0], &cuda.module);
    (p_cuda.text(), p_ocl.text())
}

// ------------------------------------------------------------ §3.2 problems

#[derive(Debug, Clone, Default)]
pub struct ProblemStats {
    pub per_bench: Vec<(String, usize, usize, usize, usize)>, // ok, crash, invalid, timeout
    pub total_evals: usize,
    pub total_ok: usize,
    pub total_crash: usize,
    pub total_invalid: usize,
    pub total_timeout: usize,
}

/// §3.2: outcome buckets over the full stream × all benchmarks. Reuses
/// the fig2 exploration counters when available.
pub fn problem_stats(rows: &[Fig2Row], n_seqs: usize) -> ProblemStats {
    let mut st = ProblemStats::default();
    for r in rows {
        st.per_bench.push((
            r.bench.clone(),
            r.n_ok,
            r.n_crash,
            r.n_invalid,
            r.n_timeout,
        ));
        st.total_ok += r.n_ok;
        st.total_crash += r.n_crash;
        st.total_invalid += r.n_invalid;
        st.total_timeout += r.n_timeout;
        st.total_evals += n_seqs;
    }
    st
}

// ------------------------------------------------------------ Fig. 7

#[derive(Debug, Clone)]
pub struct Fig7Result {
    /// K → geomean speedup over the OpenCL baseline (with -O0 fallback),
    /// for the three strategies
    pub ks: Vec<usize>,
    pub knn: Vec<f64>,
    pub random: Vec<f64>,
    pub itergraph: Vec<f64>,
    /// reference line: geomean of each benchmark's own best (Fig. 2)
    pub best_reference: f64,
}

/// Fig. 7: leave-one-out evaluation of cosine-kNN sequence suggestion vs
/// random selection vs IterGraph.
pub fn fig7_features(ctx: &mut ExpCtx, table1: &[Fig2Row]) -> Fig7Result {
    // feature vectors of all benchmarks (unoptimized OpenCL IR)
    let feats: Vec<(String, FeatureVector)> = ctx.feature_vectors();
    // a benchmark whose DSE found nothing suggests the empty order (-O0)
    let seq_of: HashMap<String, Vec<&'static str>> = table1
        .iter()
        .map(|r| (r.bench.clone(), r.best_seq.clone().unwrap_or_default()))
        .collect();

    let ks: Vec<usize> = (1..=14).collect();
    let mut knn_g = vec![Vec::new(); ks.len()];
    let mut rnd_g = vec![Vec::new(); ks.len()];
    let mut ig_g = vec![Vec::new(); ks.len()];

    let bench_names: Vec<String> = feats.iter().map(|(n, _)| n.clone()).collect();
    for (qi, qname) in bench_names.iter().enumerate() {
        // leave-one-out reference set — only the names are needed here
        // (the feature-vector side lives inside rank_neighbors)
        let refs: Vec<&String> = bench_names
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != qi)
            .map(|(_, n)| n)
            .collect();
        // the same leave-one-out ranking the KnnSeeded strategy uses
        let order = rank_neighbors(qi, &feats);
        let base = ctx.explorer(qname).baseline_time_us;

        // ---- kNN: evaluate the K most-similar benchmarks' sequences,
        // keeping the best-so-far (with -O0 as the safe fallback) ----
        {
            let mut cur = base;
            let mut prefix = Vec::new();
            for &(gi, _sim) in &order {
                let seq = seq_of[&feats[gi].0].clone();
                let ev = ctx.explorer(qname).evaluate(&seq);
                if ev.status.is_ok() {
                    cur = cur.min(ev.time_us);
                }
                prefix.push(cur);
            }
            for (kidx, &k) in ks.iter().enumerate() {
                let t = prefix.get(k - 1).copied().unwrap_or(*prefix.last().unwrap());
                knn_g[kidx].push(base / t);
            }
        }

        // ---- random selection (n_random_draws draws, geomean) ----
        {
            let mut rng = Rng::new(ctx.cfg.seed ^ (qi as u64) << 8 ^ 0x7A11);
            let mut per_k_speedups: Vec<Vec<f64>> = vec![Vec::new(); ks.len()];
            for _ in 0..ctx.cfg.n_random_draws {
                let mut idx: Vec<usize> = (0..refs.len()).collect();
                rng.shuffle(&mut idx);
                let mut cur = base;
                let mut prefix = Vec::new();
                for &ri in &idx {
                    let seq = seq_of[refs[ri]].clone();
                    let ev = ctx.explorer(qname).evaluate(&seq);
                    if ev.status.is_ok() {
                        cur = cur.min(ev.time_us);
                    }
                    prefix.push(cur);
                }
                for (kidx, &k) in ks.iter().enumerate() {
                    let t = prefix.get(k - 1).copied().unwrap_or(*prefix.last().unwrap());
                    per_k_speedups[kidx].push(base / t);
                }
            }
            for (kidx, sp) in per_k_speedups.into_iter().enumerate() {
                rnd_g[kidx].push(geomean(&sp));
            }
        }

        // ---- IterGraph: build on the other 14, sample K sequences ----
        {
            let train: Vec<Vec<&'static str>> = refs
                .iter()
                .map(|&n| seq_of[n].clone())
                .collect();
            let graph = IterGraph::build(&train);
            let samples = graph.sample_k(*ks.last().unwrap(), ctx.cfg.seed ^ 0x16E2);
            let mut cur = base;
            let mut prefix = Vec::new();
            for s in &samples {
                let names: Vec<&'static str> = s
                    .iter()
                    .filter_map(|p| {
                        crate::passes::registry_names().iter().copied().find(|n| n == p)
                    })
                    .collect();
                let ev = ctx.explorer(qname).evaluate(&names);
                if ev.status.is_ok() {
                    cur = cur.min(ev.time_us);
                }
                prefix.push(cur);
            }
            for (kidx, &k) in ks.iter().enumerate() {
                let t = prefix.get(k - 1).copied().unwrap_or(*prefix.last().unwrap());
                ig_g[kidx].push(base / t);
            }
        }
    }

    let best_reference = geomean(
        &table1
            .iter()
            .map(|r| r.speedup_over_llvm())
            .collect::<Vec<_>>(),
    );
    Fig7Result {
        ks: ks.clone(),
        knn: knn_g.iter().map(|v| geomean(v)).collect(),
        random: rnd_g.iter().map(|v| geomean(v)).collect(),
        itergraph: ig_g.iter().map(|v| geomean(v)).collect(),
        best_reference,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> ExpCtx {
        ExpCtx::new(ExpConfig {
            n_seqs: 30,
            seed: 7,
            target: Target::gp104(),
            n_perms: 10,
            n_random_draws: 5,
            jobs: 2,
            ..ExpConfig::default()
        })
    }

    #[test]
    fn winner_alloc_info_reports_budget_respecting_allocations() {
        let t = Target::gp104();
        let (regs, _spills, occ) = winner_alloc_info("GEMM", None, &t).unwrap();
        assert!(regs > 0, "a real kernel allocates at least one register");
        assert!(regs <= t.regs.max_per_thread, "allocator respects the budget");
        assert!(occ > 0.0 && occ <= 1.0);
        // unknown benchmarks render as "no info", not a panic
        assert!(winner_alloc_info("NOPE", None, &t).is_none());
    }

    #[test]
    fn fig6_patterns_differ() {
        let (cuda, ocl) = fig6_load_patterns();
        // the OpenCL flavour carries the cvt/shl/add chain; CUDA doesn't
        // have more cvt than loads
        let count = |s: &str, pat: &str| s.matches(pat).count();
        assert!(count(&ocl, "cvt.s64.s32") > count(&cuda, "cvt.s64.s32"));
        assert!(ocl.contains("ld.global.f32"));
        assert!(cuda.contains("ld.global.f32"));
    }

    #[test]
    fn fig2_on_subset_has_expected_shape() {
        // run the full pipeline on a tiny stream; verify invariants
        let mut ctx = tiny_ctx();
        let rows = fig2_table1(&mut ctx);
        assert_eq!(rows.len(), 19);
        for r in &rows {
            assert!(r.t_phase_us <= r.t_llvm_us * 1.0001, "{}", r.bench);
            assert!(r.speedup_over_opencl() >= 0.99, "{}", r.bench);
        }
        let conv = rows.iter().find(|r| r.bench == "2DCONV").unwrap();
        assert!(
            conv.speedup_over_opencl() < 1.05,
            "2DCONV must not improve (paper Table 1 note)"
        );
        let (g_cuda, g_ocl, _, _) = fig2_geomeans(&rows);
        assert!(g_ocl >= 1.0);
        assert!(g_cuda > 0.5);
    }
}
