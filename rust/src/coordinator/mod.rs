//! L3 coordinator: experiment drivers that regenerate every table and
//! figure of the paper, report writers, and the CLI.

pub mod cli;
pub mod experiments;
pub mod report;

pub use experiments::{ExpConfig, ExpCtx};
