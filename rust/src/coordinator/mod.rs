//! The coordinator layer (L4): experiment drivers that regenerate every
//! table and figure of the paper, report writers, and the CLI.
//!
//! * [`cli`] — the hand-rolled argument parser and dispatch for the
//!   `repro` binary: `explore` / `merge` expose the raw engine (and its
//!   sharded multi-process form), `fig2`…`fig7` / `table1` / `problems`
//!   / `amd` / `all` regenerate the paper artifacts, `passes` lists the
//!   registry. The full flag reference lives in `docs/CLI.md`.
//! * [`experiments`] — [`ExpConfig`] (stream size, seed, target, jobs,
//!   shard slice, verify-each) and [`ExpCtx`], which builds every
//!   benchmark's evaluation context in parallel — golden buffers come
//!   from the AOT artifacts when available, the interpreter otherwise —
//!   and owns the per-benchmark caches; one driver per figure rides on
//!   [`ExpCtx::explore_all`] (or [`ExpCtx::explore_shard`] for a
//!   `--shard I/N` slice).
//! * [`report`] — console tables and the JSON dumps under `results/`.
//! * [`serve`] — the `repro serve` daemon: newline-delimited JSON
//!   explore/transfer queries over stdin/stdout, answered from the warm
//!   `--store DIR` artifact store with per-query hit/miss/compile
//!   accounting.

pub mod cli;
pub mod experiments;
pub mod report;
pub mod serve;

pub use experiments::{ExpConfig, ExpCtx};
