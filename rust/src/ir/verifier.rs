//! IR well-formedness checks.
//!
//! Run after construction and (in tests / property tests) after every pass:
//! a transform that breaks SSA dominance or CFG/phi consistency is a
//! compiler bug of the "crash" category, distinct from the *semantic* bugs
//! the validator catches by executing the code.

use std::collections::HashSet;

use super::function::Function;
use super::inst::{InstId, Op};
use super::module::Module;
use super::value::Value;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyError(pub String);

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "verify: {}", self.0)
    }
}
impl std::error::Error for VerifyError {}

fn err<T>(msg: String) -> Result<T, VerifyError> {
    Err(VerifyError(msg))
}

pub fn verify_module(m: &Module) -> Result<(), VerifyError> {
    for k in &m.kernels {
        verify_function(k)?;
    }
    Ok(())
}

pub fn verify_function(f: &Function) -> Result<(), VerifyError> {
    // through the pass layer's one-shot constructor: analysis
    // construction stays centralized in passes/ (the verifier runs on
    // arbitrary module states, so there is no pipeline cache to share)
    let dt = crate::passes::analyses::dom_of(f);
    let pos = f.inst_positions();

    // every reachable block: non-empty, terminator last and only last,
    // succ/pred symmetry, phi arity matches preds, phis lead the block
    for bb in f.block_ids() {
        if !dt.is_reachable(bb) {
            continue;
        }
        let blk = f.block(bb);
        let live: Vec<InstId> = blk
            .insts
            .iter()
            .copied()
            .filter(|&i| !f.inst(i).is_nop())
            .collect();
        let Some(&last) = live.last() else {
            return err(format!("block {} has no terminator", blk.name));
        };
        if !f.inst(last).op.is_terminator() {
            return err(format!("block {} does not end in terminator", blk.name));
        }
        let mut seen_non_phi = false;
        for &i in &live {
            let inst = f.inst(i);
            if inst.op.is_terminator() && i != last {
                return err(format!("block {} has terminator mid-block", blk.name));
            }
            match inst.op {
                Op::Phi => {
                    if seen_non_phi {
                        return err(format!("phi %{} after non-phi in {}", i.0, blk.name));
                    }
                    if inst.args().len() != blk.preds.len() {
                        return err(format!(
                            "phi %{} arity {} != preds {} in {}",
                            i.0,
                            inst.args().len(),
                            blk.preds.len(),
                            blk.name
                        ));
                    }
                }
                _ => seen_non_phi = true,
            }
            if let Some(n) = inst.op.num_args() {
                if inst.args().len() != n {
                    return err(format!(
                        "%{}: {} expects {} args, has {}",
                        i.0,
                        inst.op.mnemonic(),
                        n,
                        inst.args().len()
                    ));
                }
            }
        }
        let expected_succs = match f.inst(last).op {
            Op::Br => 1,
            Op::CondBr => 2,
            Op::Ret => 0,
            _ => unreachable!(),
        };
        if blk.succs.len() != expected_succs {
            return err(format!(
                "block {}: {} succs for {:?}",
                blk.name,
                blk.succs.len(),
                f.inst(last).op
            ));
        }
        for &s in &blk.succs {
            if (s.0 as usize) >= f.blocks.len() {
                return err(format!("block {}: succ out of range", blk.name));
            }
            if !f.block(s).preds.contains(&bb) {
                return err(format!(
                    "edge {} -> {} missing in pred list",
                    blk.name,
                    f.block(s).name
                ));
            }
        }
        for &p in &blk.preds {
            if !f.block(p).succs.contains(&bb) {
                return err(format!(
                    "pred edge {} -> {} missing in succ list",
                    f.block(p).name,
                    blk.name
                ));
            }
        }
    }

    // no instruction appears in two blocks
    let mut seen: HashSet<InstId> = HashSet::new();
    for bb in f.block_ids() {
        for &i in &f.block(bb).insts {
            if !seen.insert(i) {
                return err(format!("instruction %{} linked twice", i.0));
            }
        }
    }

    // SSA dominance: each use of Inst(v) is dominated by its definition.
    for bb in f.block_ids() {
        if !dt.is_reachable(bb) {
            continue;
        }
        let blk = f.block(bb);
        for (use_idx, &i) in blk.insts.iter().enumerate() {
            let inst = f.inst(i);
            if inst.is_nop() {
                continue;
            }
            for (arg_idx, &a) in inst.args().iter().enumerate() {
                let Value::Inst(def) = a else { continue };
                if f.inst(def).is_nop() {
                    return err(format!("%{}: use of deleted value %{}", i.0, def.0));
                }
                let Some(&(def_bb, def_idx)) = pos.get(&def) else {
                    return err(format!("%{}: use of unplaced value %{}", i.0, def.0));
                };
                if inst.op == Op::Phi {
                    // incoming value must dominate the end of the pred edge
                    let pred = blk.preds[arg_idx];
                    if !dt.is_reachable(pred) {
                        continue;
                    }
                    if !dt.dominates(def_bb, pred) {
                        return err(format!(
                            "phi %{} incoming %{} does not dominate pred {}",
                            i.0,
                            def.0,
                            f.block(pred).name
                        ));
                    }
                } else if def_bb == bb {
                    if def_idx >= use_idx {
                        return err(format!("%{}: use before def of %{}", i.0, def.0));
                    }
                } else if !dt.dominates(def_bb, bb) {
                    return err(format!(
                        "%{}: def %{} in {} does not dominate use in {}",
                        i.0,
                        def.0,
                        f.block(def_bb).name,
                        f.block(bb).name
                    ));
                }
            }
            for &a in inst.args() {
                if let Value::Arg(n) = a {
                    if n as usize >= f.params.len() {
                        return err(format!("%{}: arg index {} out of range", i.0, n));
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{AddrSpace, Block, BlockId, Inst, KernelBuilder, Ty};

    #[test]
    fn rejects_missing_terminator() {
        let mut f = Function::new("bad");
        let e = f.add_block(Block::new("entry"));
        f.entry = e;
        let r = verify_function(&f);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_use_before_def() {
        let mut b = KernelBuilder::new("k", &[("a", Ty::Ptr(AddrSpace::Global))]);
        let v = b.fadd(b.fc(1.0), b.fc(2.0));
        b.store(b.param(0), b.i(0), v);
        let mut f = b.finish();
        // swap the fadd after the store chain's first inst
        let entry = BlockId(0);
        let insts = f.block(entry).insts.clone();
        let mut reordered = insts.clone();
        reordered.swap(0, insts.len() - 2);
        f.block_mut(entry).insts = reordered;
        assert!(verify_function(&f).is_err());
    }

    #[test]
    fn rejects_phi_arity_mismatch() {
        let mut b = KernelBuilder::new("k", &[("a", Ty::Ptr(AddrSpace::Global))]);
        let n = b.i(4);
        b.for_loop("i", b.i(0), n, 1, |b, iv| {
            let v = b.load(b.param(0), iv);
            b.store(b.param(0), iv, v);
        });
        let mut f = b.finish();
        // find the phi and drop one operand
        let phi = (0..f.insts.len())
            .map(crate::ir::InstId::from_usize)
            .find(|&i| f.inst(i).op == Op::Phi)
            .unwrap();
        f.inst_mut(phi).remove_arg(0);
        assert!(verify_function(&f).is_err());
    }

    #[test]
    fn accepts_wellformed_nest() {
        let mut b = KernelBuilder::new("k", &[("a", Ty::Ptr(AddrSpace::Global))]);
        let n = b.i(4);
        b.for_loop("i", b.i(0), n, 1, |b, i| {
            let n2 = b.i(4);
            b.for_loop("j", b.i(0), n2, 1, |b, j| {
                let idx = {
                    let t = b.mul(i, b.i(4));
                    b.add(t, j)
                };
                let v = b.load(b.param(0), idx);
                let w = b.fmul(v, b.fc(3.0));
                b.store(b.param(0), idx, w);
            });
        });
        let f = b.finish();
        verify_function(&f).expect("clean");
    }
}
