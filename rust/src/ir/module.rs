//! Modules: a set of kernels plus the typed pipeline-wide state passes
//! communicate through (the stateful couplings phase ordering exploits).

use super::function::Function;

/// Which alias analysis is installed. In LLVM 3.9 `cfl-anders-aa`
/// existed but was *not* part of the default -O pipelines — which is why
/// the paper's winning sequences lead with it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AaPrecision {
    /// BasicAA: conservatively merges distinct global buffer params.
    #[default]
    Basic,
    /// The context-sensitive CFL-Anders summary: per OpenCL 2.0 §3.4 of
    /// the paper, distinct global buffer params cannot race, so memory
    /// passes may treat them as non-aliasing.
    CflAnders,
}

/// The installed alias summary and its freshness. The summary is
/// computed over addressing *as it looked when `cfl-anders-aa` ran*;
/// passes that rewrite addressing (`loop-reduce`, `bb-vectorize`) mark
/// it stale, and `sink`'s unsound fast path consults the stale summary
/// (documented bug model #4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AliasSummary {
    pub precision: AaPrecision,
    pub stale: bool,
}

/// CFG freshness relative to the loop analyses. `jump-threading` /
/// `simplifycfg` restructure without refreshing loop analyses and set
/// `dirty`; `loop-unswitch` consults a cached invariance summary that
/// this staleness corrupts (documented bug model #2); passes that
/// recompute loop analyses (`licm`, `gvn`, `loop-reduce`) clear it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CfgFacts {
    pub dirty: bool,
}

/// Where allocas live. After `nvptx-lower-alloca` they are
/// `__local_depot` accesses that `mem2reg`/`sroa` can no longer raise
/// (running them afterwards is a no-op, like the real passes on
/// address-space-qualified allocas).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllocaForm {
    /// Generic allocas, still promotable to SSA.
    #[default]
    Generic,
    /// Lowered into the per-thread `__local_depot` (PTX `.local`).
    Depot,
}

/// Outlining state. `loop-extract-single` outlined a loop body, which
/// codegen charges a one-off call overhead for (§3.4 SYR2K observation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Outlining {
    pub loops_extracted: bool,
}

/// The typed inter-pass state — formerly four ad-hoc module bools
/// (`precise_aa`, `aa_stale`, `cfg_dirty`, `allocas_lowered`) plus
/// `loops_extracted`. The mapping is exact and the transitions are
/// bit-for-bit those of the old flags (they are load-bearing for the
/// paper's order-matters mechanism):
///
/// | old flag          | typed entry                                   |
/// |-------------------|-----------------------------------------------|
/// | `precise_aa`      | `alias.precision == AaPrecision::CflAnders`   |
/// | `aa_stale`        | `alias.stale`                                 |
/// | `cfg_dirty`       | `cfg.dirty`                                   |
/// | `allocas_lowered` | `allocas == AllocaForm::Depot`                |
/// | `loops_extracted` | `outlining.loops_extracted`                   |
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PipelineState {
    pub alias: AliasSummary,
    pub cfg: CfgFacts,
    pub allocas: AllocaForm,
    pub outlining: Outlining,
}

/// A translation unit: one PolyBench benchmark's kernel(s) plus the
/// typed state that makes pass *order* matter beyond per-pass IR
/// rewrites.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub kernels: Vec<Function>,
    pub state: PipelineState,
}

impl Module {
    pub fn new(name: impl Into<String>) -> Module {
        Module {
            name: name.into(),
            kernels: Vec::new(),
            state: PipelineState::default(),
        }
    }

    /// Is the precise (CFL-Anders) alias summary installed?
    pub fn precise_aa(&self) -> bool {
        self.state.alias.precision == AaPrecision::CflAnders
    }

    /// Was addressing rewritten since the alias summary was computed?
    pub fn aa_stale(&self) -> bool {
        self.state.alias.stale
    }

    /// Was the CFG restructured since loop analyses were last refreshed?
    pub fn cfg_dirty(&self) -> bool {
        self.state.cfg.dirty
    }

    /// Did `nvptx-lower-alloca` run (allocas are depot accesses)?
    pub fn allocas_lowered(&self) -> bool {
        self.state.allocas == AllocaForm::Depot
    }

    /// Did `loop-extract-single` outline a loop body?
    pub fn loops_extracted(&self) -> bool {
        self.state.outlining.loops_extracted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_module_state_matches_old_flag_defaults() {
        let m = Module::new("t");
        assert!(!m.precise_aa());
        assert!(!m.aa_stale());
        assert!(!m.cfg_dirty());
        assert!(!m.allocas_lowered());
        assert!(!m.loops_extracted());
        assert_eq!(m.state, PipelineState::default());
    }

    #[test]
    fn typed_entries_map_onto_the_old_flags() {
        let mut m = Module::new("t");
        m.state.alias.precision = AaPrecision::CflAnders;
        assert!(m.precise_aa());
        m.state.alias.stale = true;
        assert!(m.aa_stale());
        m.state.cfg.dirty = true;
        assert!(m.cfg_dirty());
        m.state.allocas = AllocaForm::Depot;
        assert!(m.allocas_lowered());
        m.state.outlining.loops_extracted = true;
        assert!(m.loops_extracted());
    }
}
