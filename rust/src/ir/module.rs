//! Modules: a set of kernels plus pipeline-wide state that passes
//! communicate through (the stateful couplings phase ordering exploits).

use super::function::Function;

/// A translation unit: one PolyBench benchmark's kernel(s) plus the state
/// that makes pass *order* matter beyond per-pass IR rewrites.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub kernels: Vec<Function>,
    /// Installed by `cfl-anders-aa`: a context-sensitive alias summary
    /// that (per OpenCL 2.0 §3.4 of the paper) lets memory passes treat
    /// distinct global buffer params as non-aliasing. Without it, BasicAA
    /// conservatively merges them — which is why -O3 alone gets nothing.
    pub precise_aa: bool,
    /// The precise-AA summary is computed over addressing as it looked
    /// when `cfl-anders-aa` ran. Passes that rewrite addressing
    /// (`loop-reduce`, `bb-vectorize`) set this; `sink`'s unsound fast
    /// path consults the stale summary (documented bug model #4).
    pub aa_stale: bool,
    /// `nvptx-lower-alloca` ran: allocas became `__local_depot` accesses.
    /// `mem2reg`/`sroa` can no longer raise them (precondition violation =
    /// the paper's compile-crash bucket).
    pub allocas_lowered: bool,
    /// `loop-extract-single` outlined a loop body (affects codegen
    /// call overhead modelling; §3.4 SYR2K observation).
    pub loops_extracted: bool,
    /// CFG was restructured by `jump-threading`/`simplifycfg` since loop
    /// analyses were last refreshed. `loop-unswitch` consults a cached
    /// invariance summary that this invalidates (documented bug model #2);
    /// passes that recompute loop analyses (`licm`, `gvn`, `loop-reduce`)
    /// clear it.
    pub cfg_dirty: bool,
}

impl Module {
    pub fn new(name: impl Into<String>) -> Module {
        Module {
            name: name.into(),
            kernels: Vec::new(),
            precise_aa: false,
            aa_stale: false,
            allocas_lowered: false,
            loops_extracted: false,
            cfg_dirty: false,
        }
    }
}
