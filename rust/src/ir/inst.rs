//! Instructions and opcodes.

use super::types::Ty;
use super::value::Value;

/// Maximum operand count. Phi arity is bounded by predecessor count; our
/// structured kernels never exceed 4 predecessors (verifier-enforced).
pub const MAX_ARGS: usize = 4;

/// Index into `Function::insts`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstId(pub u32);

impl InstId {
    pub fn from_usize(i: usize) -> InstId {
        InstId(i as u32)
    }
}

/// Comparison predicates (shared by ICmp/FCmp; FCmp is ordered).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpPred {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpPred {
    pub fn eval_i(self, a: i64, b: i64) -> bool {
        match self {
            CmpPred::Eq => a == b,
            CmpPred::Ne => a != b,
            CmpPred::Lt => a < b,
            CmpPred::Le => a <= b,
            CmpPred::Gt => a > b,
            CmpPred::Ge => a >= b,
        }
    }
    pub fn eval_f(self, a: f32, b: f32) -> bool {
        match self {
            CmpPred::Eq => a == b,
            CmpPred::Ne => a != b,
            CmpPred::Lt => a < b,
            CmpPred::Le => a <= b,
            CmpPred::Gt => a > b,
            CmpPred::Ge => a >= b,
        }
    }
}

/// Opcodes. A deliberately LLVM-shaped subset: enough to express every
/// PolyBench/GPU kernel and every transformation the paper's Table 1
/// sequences perform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Dead slot in the arena (left behind by deleting passes; skipped
    /// everywhere, compacted by `Function::compact`).
    Nop,
    // ---- integer arithmetic: args [a, b] ----
    Add,
    Sub,
    Mul,
    SDiv,
    SRem,
    Shl,
    AShr,
    And,
    Or,
    Xor,
    // ---- float arithmetic ----
    FAdd,
    FSub,
    FMul,
    FDiv,
    /// args [a]
    FSqrt,
    FAbs,
    FNeg,
    FExp,
    /// args [cond, then, else]
    Select,
    ICmp(CmpPred),
    FCmp(CmpPred),
    // ---- casts: args [a] ----
    /// i32 -> i64 sign extension (the `cvt.s64.s32` of Fig. 6).
    Sext,
    Trunc,
    SiToFp,
    FpToSi,
    // ---- memory ----
    /// args [ptr, byte_offset:i64] -> ptr. Address arithmetic is explicit,
    /// which is what makes the Fig. 6 load-pattern difference observable
    /// and what `loop-reduce` rewrites.
    PtrAdd,
    /// args [ptr] -> f32
    Load,
    /// args [ptr, value]; no result.
    Store,
    /// args [ptr, value] -> f32: atomically `*ptr += value`, returning
    /// the old value. The SIMT interpreter runs lanes sequentially so
    /// atomics are trivially sequentially consistent; the cost model
    /// prices the contention they imply on real hardware.
    AtomAdd,
    /// args [ptr, value] -> f32: atomically `*ptr = max(*ptr, value)`,
    /// returning the old value.
    AtomMax,
    /// args [size_bytes:imm] -> Ptr(Local). Created by `reg2mem`, lowered
    /// by `nvptx-lower-alloca` into the `__local_depot`.
    Alloca,
    /// One arg per predecessor, aligned with `Block::preds`.
    Phi,
    // ---- terminators ----
    /// Unconditional branch to `Block::succs[0]`.
    Br,
    /// args [cond]; succs[0] = taken, succs[1] = fallthrough.
    CondBr,
    Ret,
}

impl Op {
    pub fn is_terminator(self) -> bool {
        matches!(self, Op::Br | Op::CondBr | Op::Ret)
    }
    /// Instruction has a side effect on memory or control flow (cannot be
    /// removed just because its value is unused).
    pub fn has_side_effect(self) -> bool {
        matches!(
            self,
            Op::Store | Op::AtomAdd | Op::AtomMax | Op::Br | Op::CondBr | Op::Ret
        )
    }
    pub fn is_memory(self) -> bool {
        matches!(self, Op::Load | Op::Store | Op::AtomAdd | Op::AtomMax)
    }
    /// Instruction may mutate memory: the barrier every forwarding /
    /// motion / dead-store screen must respect (atomics both read and
    /// write their location).
    pub fn may_write_memory(self) -> bool {
        matches!(self, Op::Store | Op::AtomAdd | Op::AtomMax)
    }
    /// Pure value computation: safe to hoist/sink/CSE if operands allow.
    pub fn is_pure(self) -> bool {
        !matches!(
            self,
            Op::Nop
                | Op::Load
                | Op::Store
                | Op::AtomAdd
                | Op::AtomMax
                | Op::Alloca
                | Op::Phi
                | Op::Br
                | Op::CondBr
                | Op::Ret
        )
    }
    /// Commutative binary ops (used by instcombine/reassociate/gvn
    /// canonicalization).
    pub fn is_commutative(self) -> bool {
        matches!(
            self,
            Op::Add | Op::Mul | Op::And | Op::Or | Op::Xor | Op::FAdd | Op::FMul
        )
    }
    pub fn num_args(self) -> Option<usize> {
        Some(match self {
            Op::Nop | Op::Br | Op::Ret => 0,
            Op::FSqrt
            | Op::FAbs
            | Op::FNeg
            | Op::FExp
            | Op::Sext
            | Op::Trunc
            | Op::SiToFp
            | Op::FpToSi
            | Op::Load
            | Op::CondBr
            | Op::Alloca => 1,
            Op::Select => 3,
            Op::Phi => return None, // pred-count dependent
            _ => 2,
        })
    }
    pub fn mnemonic(self) -> &'static str {
        match self {
            Op::Nop => "nop",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::SDiv => "sdiv",
            Op::SRem => "srem",
            Op::Shl => "shl",
            Op::AShr => "ashr",
            Op::And => "and",
            Op::Or => "or",
            Op::Xor => "xor",
            Op::FAdd => "fadd",
            Op::FSub => "fsub",
            Op::FMul => "fmul",
            Op::FDiv => "fdiv",
            Op::FSqrt => "fsqrt",
            Op::FAbs => "fabs",
            Op::FNeg => "fneg",
            Op::FExp => "fexp",
            Op::Select => "select",
            Op::ICmp(_) => "icmp",
            Op::FCmp(_) => "fcmp",
            Op::Sext => "sext",
            Op::Trunc => "trunc",
            Op::SiToFp => "sitofp",
            Op::FpToSi => "fptosi",
            Op::PtrAdd => "ptradd",
            Op::Load => "load",
            Op::Store => "store",
            Op::AtomAdd => "atom.add",
            Op::AtomMax => "atom.max",
            Op::Alloca => "alloca",
            Op::Phi => "phi",
            Op::Br => "br",
            Op::CondBr => "condbr",
            Op::Ret => "ret",
        }
    }
}

/// An instruction: opcode, result type, flat operand array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Inst {
    pub op: Op,
    pub ty: Ty,
    args: [Value; MAX_ARGS],
    nargs: u8,
}

impl Inst {
    pub fn new(op: Op, ty: Ty, args: &[Value]) -> Inst {
        assert!(args.len() <= MAX_ARGS, "too many operands for {op:?}");
        let mut a = [Value::ImmI(0); MAX_ARGS];
        a[..args.len()].copy_from_slice(args);
        Inst {
            op,
            ty,
            args: a,
            nargs: args.len() as u8,
        }
    }
    pub fn nop() -> Inst {
        Inst::new(Op::Nop, Ty::Void, &[])
    }
    pub fn args(&self) -> &[Value] {
        &self.args[..self.nargs as usize]
    }
    pub fn args_mut(&mut self) -> &mut [Value] {
        &mut self.args[..self.nargs as usize]
    }
    pub fn set_args(&mut self, args: &[Value]) {
        assert!(args.len() <= MAX_ARGS);
        self.args[..args.len()].copy_from_slice(args);
        self.nargs = args.len() as u8;
    }
    pub fn push_arg(&mut self, v: Value) {
        assert!((self.nargs as usize) < MAX_ARGS, "phi arity overflow");
        self.args[self.nargs as usize] = v;
        self.nargs += 1;
    }
    pub fn remove_arg(&mut self, idx: usize) {
        let n = self.nargs as usize;
        assert!(idx < n);
        for i in idx..n - 1 {
            self.args[i] = self.args[i + 1];
        }
        self.nargs -= 1;
    }
    pub fn is_nop(&self) -> bool {
        self.op == Op::Nop
    }
}
