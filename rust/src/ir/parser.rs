//! Textual IR parser — the inverse of [`printer`](super::printer).
//!
//! Lets tests and debugging sessions write kernels as text, and makes
//! printer output round-trippable. The grammar is exactly what
//! `print_function` emits:
//!
//! ```text
//! kernel @name(Ptr(Global) %a, Ptr(Global) %b) {
//! entry:
//!   %3 = add %arg0, 4
//!   store %6, 1.0
//!   condbr %0, if.then, if.join
//! ...
//! }
//! ```
//!
//! Value tokens: `%N` (instruction result), `%argN`, integer and float
//! literals, `@gid.D`, `@gsz.D`. Instruction ids in the text are
//! renumbered densely on parse (like LLVM's text parser — the property
//! the AOT HLO-text interchange relies on, too).

use std::collections::HashMap;

use super::block::{Block, BlockId};
use super::function::{Function, Param};
use super::inst::{CmpPred, Inst, InstId, Op};
use super::types::{AddrSpace, Ty};
use super::value::Value;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse: {}", self.0)
    }
}
impl std::error::Error for ParseError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError(msg.into()))
}

/// Parse one kernel from printer-format text.
pub fn parse_function(text: &str) -> Result<Function, ParseError> {
    let mut lines = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .peekable();

    // header
    let header = lines.next().ok_or(ParseError("empty input".into()))?;
    let header = header
        .strip_prefix("kernel @")
        .ok_or(ParseError("missing 'kernel @'".into()))?;
    let open = header.find('(').ok_or(ParseError("missing '('".into()))?;
    let close = header.rfind(')').ok_or(ParseError("missing ')'".into()))?;
    let name = header[..open].to_string();
    let mut f = Function::new(name);
    let params_str = &header[open + 1..close];
    if !params_str.trim().is_empty() {
        for p in params_str.split(',') {
            let p = p.trim();
            let (ty_str, pname) = p
                .rsplit_once(" %")
                .ok_or_else(|| ParseError(format!("bad param '{p}'")))?;
            let ty = parse_ty(ty_str)?;
            f.params.push(Param {
                name: pname.to_string(),
                ty,
                noalias_by_spec: ty.is_ptr(),
            });
        }
    }

    // first pass: collect block labels in order (lines ending with ':'
    // up to an optional comment)
    #[derive(Default)]
    struct RawBlock {
        name: String,
        lines: Vec<String>,
    }
    let mut raw: Vec<RawBlock> = Vec::new();
    for line in lines {
        if line == "}" {
            break;
        }
        let no_comment = match line.find(';') {
            Some(k) => line[..k].trim_end(),
            None => line,
        };
        if no_comment.is_empty() {
            continue;
        }
        if let Some(label) = no_comment.strip_suffix(':') {
            raw.push(RawBlock {
                name: label.trim().to_string(),
                lines: Vec::new(),
            });
        } else {
            let cur = raw
                .last_mut()
                .ok_or(ParseError("instruction before first label".into()))?;
            cur.lines.push(no_comment.to_string());
        }
    }
    if raw.is_empty() {
        return err("no blocks");
    }
    let mut block_ids: HashMap<String, BlockId> = HashMap::new();
    for rb in &raw {
        let id = f.add_block(Block::new(rb.name.clone()));
        if block_ids.insert(rb.name.clone(), id).is_some() {
            return err(format!("duplicate block label {}", rb.name));
        }
    }
    f.entry = BlockId(0);

    // second pass: instructions; text ids → dense new ids
    let mut id_map: HashMap<u32, InstId> = HashMap::new();
    // pre-scan destinations so forward references (phis) resolve
    struct PendingInst {
        bb: BlockId,
        dst: Option<u32>,
        op_str: String,
        rest: String,
    }
    let mut pending: Vec<PendingInst> = Vec::new();
    for rb in &raw {
        let bb = block_ids[&rb.name];
        for line in &rb.lines {
            let (dst, rhs) = if let Some((lhs, rhs)) = line.split_once('=') {
                let lhs = lhs.trim();
                let n: u32 = lhs
                    .strip_prefix('%')
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| ParseError(format!("bad destination '{lhs}'")))?;
                (Some(n), rhs.trim())
            } else {
                (None, line.as_str())
            };
            let (op_str, rest) = match rhs.split_once(char::is_whitespace) {
                Some((o, r)) => (o.to_string(), r.trim().to_string()),
                None => (rhs.to_string(), String::new()),
            };
            pending.push(PendingInst {
                bb,
                dst,
                op_str,
                rest,
            });
        }
    }
    // allocate ids in order
    for p in &pending {
        let id = f.add_inst(Inst::nop());
        if let Some(d) = p.dst {
            id_map.insert(d, id);
        }
        f.block_mut(p.bb).insts.push(id);
    }
    // fill bodies
    let all_ids: Vec<InstId> = f
        .block_ids()
        .flat_map(|bb| f.block(bb).insts.clone())
        .collect();
    for (p, id) in pending.iter().zip(all_ids) {
        let (op, ty, args, succs) = parse_inst(&p.op_str, &p.rest, &id_map, &block_ids)?;
        f.insts[id.0 as usize] = Inst::new(op, ty, &args);
        if !succs.is_empty() {
            f.block_mut(p.bb).succs = succs;
        }
    }
    f.recompute_preds();
    Ok(f)
}

fn parse_ty(s: &str) -> Result<Ty, ParseError> {
    match s.trim() {
        "I1" => Ok(Ty::I1),
        "I32" => Ok(Ty::I32),
        "I64" => Ok(Ty::I64),
        "F32" => Ok(Ty::F32),
        "Ptr(Global)" => Ok(Ty::Ptr(AddrSpace::Global)),
        "Ptr(Local)" => Ok(Ty::Ptr(AddrSpace::Local)),
        other => err(format!("unknown type '{other}'")),
    }
}

fn parse_value(tok: &str, ids: &HashMap<u32, InstId>) -> Result<Value, ParseError> {
    let tok = tok.trim();
    if let Some(rest) = tok.strip_prefix("%arg") {
        return rest
            .parse::<u16>()
            .map(Value::Arg)
            .map_err(|_| ParseError(format!("bad arg '{tok}'")));
    }
    if let Some(rest) = tok.strip_prefix('%') {
        let n: u32 = rest
            .parse()
            .map_err(|_| ParseError(format!("bad value '{tok}'")))?;
        return ids
            .get(&n)
            .map(|&i| Value::Inst(i))
            .ok_or_else(|| ParseError(format!("undefined %{n}")));
    }
    if let Some(rest) = tok.strip_prefix("@gid.") {
        return rest
            .parse::<u8>()
            .map(Value::GlobalId)
            .map_err(|_| ParseError(format!("bad gid '{tok}'")));
    }
    if let Some(rest) = tok.strip_prefix("@gsz.") {
        return rest
            .parse::<u8>()
            .map(Value::GlobalSize)
            .map_err(|_| ParseError(format!("bad gsz '{tok}'")));
    }
    if tok.contains('.') || tok.contains("inf") || tok.contains("NaN") {
        return tok
            .parse::<f32>()
            .map(Value::imm_f)
            .map_err(|_| ParseError(format!("bad float '{tok}'")));
    }
    tok.parse::<i64>()
        .map(Value::ImmI)
        .map_err(|_| ParseError(format!("bad int '{tok}'")))
}

fn parse_pred(s: &str) -> Result<CmpPred, ParseError> {
    Ok(match s {
        "eq" => CmpPred::Eq,
        "ne" => CmpPred::Ne,
        "lt" => CmpPred::Lt,
        "le" => CmpPred::Le,
        "gt" => CmpPred::Gt,
        "ge" => CmpPred::Ge,
        other => return err(format!("unknown predicate '{other}'")),
    })
}

#[allow(clippy::type_complexity)]
fn parse_inst(
    op_str: &str,
    rest: &str,
    ids: &HashMap<u32, InstId>,
    blocks: &HashMap<String, BlockId>,
) -> Result<(Op, Ty, Vec<Value>, Vec<BlockId>), ParseError> {
    let args = |rest: &str| -> Result<Vec<Value>, ParseError> {
        if rest.trim().is_empty() {
            return Ok(Vec::new());
        }
        rest.split(',').map(|t| parse_value(t, ids)).collect()
    };
    let simple = |op: Op, ty: Ty| -> Result<(Op, Ty, Vec<Value>, Vec<BlockId>), ParseError> {
        Ok((op, ty, args(rest)?, Vec::new()))
    };
    match op_str {
        "add" => simple(Op::Add, Ty::I32),
        "sub" => simple(Op::Sub, Ty::I32),
        "mul" => simple(Op::Mul, Ty::I32),
        "sdiv" => simple(Op::SDiv, Ty::I32),
        "srem" => simple(Op::SRem, Ty::I32),
        "shl" => simple(Op::Shl, Ty::I64),
        "ashr" => simple(Op::AShr, Ty::I64),
        "and" => simple(Op::And, Ty::I1),
        "or" => simple(Op::Or, Ty::I1),
        "xor" => simple(Op::Xor, Ty::I32),
        "fadd" => simple(Op::FAdd, Ty::F32),
        "fsub" => simple(Op::FSub, Ty::F32),
        "fmul" => simple(Op::FMul, Ty::F32),
        "fdiv" => simple(Op::FDiv, Ty::F32),
        "fsqrt" => simple(Op::FSqrt, Ty::F32),
        "fabs" => simple(Op::FAbs, Ty::F32),
        "fneg" => simple(Op::FNeg, Ty::F32),
        "fexp" => simple(Op::FExp, Ty::F32),
        "select" => simple(Op::Select, Ty::F32),
        "sext" => simple(Op::Sext, Ty::I64),
        "trunc" => simple(Op::Trunc, Ty::I32),
        "sitofp" => simple(Op::SiToFp, Ty::F32),
        "fptosi" => simple(Op::FpToSi, Ty::I32),
        "ptradd" => simple(Op::PtrAdd, Ty::Ptr(AddrSpace::Global)),
        "load" => simple(Op::Load, Ty::F32),
        "store" => simple(Op::Store, Ty::Void),
        "atom.add" => simple(Op::AtomAdd, Ty::F32),
        "atom.max" => simple(Op::AtomMax, Ty::F32),
        "alloca" => simple(Op::Alloca, Ty::Ptr(AddrSpace::Local)),
        "phi" => simple(Op::Phi, Ty::I32),
        "ret" => Ok((Op::Ret, Ty::Void, Vec::new(), Vec::new())),
        "br" => {
            let target = blocks
                .get(rest.trim())
                .ok_or_else(|| ParseError(format!("unknown block '{rest}'")))?;
            Ok((Op::Br, Ty::Void, Vec::new(), vec![*target]))
        }
        "condbr" => {
            let parts: Vec<&str> = rest.split(',').map(str::trim).collect();
            if parts.len() != 3 {
                return err(format!("condbr needs cond, t, f — got '{rest}'"));
            }
            let cond = parse_value(parts[0], ids)?;
            let t = *blocks
                .get(parts[1])
                .ok_or_else(|| ParseError(format!("unknown block '{}'", parts[1])))?;
            let e = *blocks
                .get(parts[2])
                .ok_or_else(|| ParseError(format!("unknown block '{}'", parts[2])))?;
            Ok((Op::CondBr, Ty::Void, vec![cond], vec![t, e]))
        }
        cmp if cmp.starts_with("icmp.") => {
            let p = parse_pred(&cmp[5..])?;
            Ok((Op::ICmp(p), Ty::I1, args(rest)?, Vec::new()))
        }
        cmp if cmp.starts_with("fcmp.") => {
            let p = parse_pred(&cmp[5..])?;
            Ok((Op::FCmp(p), Ty::I1, args(rest)?, Vec::new()))
        }
        other => err(format!("unknown opcode '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer::print_function;
    use crate::ir::verifier::verify_function;
    use crate::ir::KernelBuilder;

    #[test]
    fn parses_simple_kernel() {
        let text = "\
kernel @saxpy(Ptr(Global) %x, Ptr(Global) %y) {
entry:
  %0 = sext @gid.0
  %1 = shl %0, 2
  %2 = ptradd %arg0, %1
  %3 = load %2
  %4 = fmul %3, 2.0
  %5 = ptradd %arg1, %1
  %6 = load %5
  %7 = fadd %4, %6
  store %5, %7
  ret
}";
        let f = parse_function(text).unwrap();
        verify_function(&f).unwrap();
        assert_eq!(f.name, "saxpy");
        assert_eq!(f.params.len(), 2);
        assert_eq!(f.num_live_insts(), 10);
    }

    #[test]
    fn parses_control_flow() {
        let text = "\
kernel @k(Ptr(Global) %a) {
entry:
  %0 = icmp.lt @gid.0, 4
  condbr %0, then, join
then:
  %2 = sext @gid.0
  %3 = shl %2, 2
  %4 = ptradd %arg0, %3
  store %4, 1.0
  br join
join:
  ret
}";
        let f = parse_function(text).unwrap();
        verify_function(&f).unwrap();
        assert_eq!(f.blocks.len(), 3);
        assert_eq!(f.block(BlockId(0)).succs.len(), 2);
    }

    /// print → parse → print must be a fixpoint on every benchmark kernel
    /// (modulo instruction renumbering, which the second print normalizes).
    #[test]
    fn roundtrip_all_benchmarks() {
        for b in crate::bench_suite::all_benchmarks() {
            let built = b.build_small(crate::bench_suite::Variant::OpenCl);
            for k in &built.module.kernels {
                let t1 = print_function(k);
                let parsed = parse_function(&t1)
                    .unwrap_or_else(|e| panic!("{}/{}: {e}\n{t1}", b.name, k.name));
                verify_function(&parsed)
                    .unwrap_or_else(|e| panic!("{}/{}: {e}", b.name, k.name));
                let t2 = print_function(&parsed);
                let t3 = print_function(&parse_function(&t2).unwrap());
                assert_eq!(t2, t3, "{}/{} not a fixpoint", b.name, k.name);
                // structural equality: same op multiset and block count
                assert_eq!(parsed.blocks.len(), k.blocks.len());
                assert_eq!(parsed.num_live_insts(), k.num_live_insts());
            }
        }
    }

    /// parsed kernels execute identically to their originals
    #[test]
    fn roundtrip_preserves_semantics() {
        use crate::sim::exec::{run_kernel, Buffers};
        let mut b = KernelBuilder::new(
            "k",
            &[("a", crate::ir::Ty::Ptr(crate::ir::AddrSpace::Global))],
        );
        let n = b.i(8);
        let (_h, acc) = b.for_loop_acc("i", b.i(0), n, 1, b.fc(0.0), |b, iv, acc| {
            let v = b.load(b.param(0), iv);
            b.fadd(acc, v)
        });
        b.store(b.param(0), b.i(0), acc);
        let f = b.finish();
        let text = print_function(&f);
        let parsed = parse_function(&text).unwrap();
        let mk = || {
            let mut bufs = Buffers::new(&[8]);
            for i in 0..8 {
                bufs.bufs[0][i] = (i + 1) as f32;
            }
            bufs
        };
        let mut b1 = mk();
        let mut b2 = mk();
        run_kernel(&f, (1, 1), &mut b1, 1_000_000).unwrap();
        run_kernel(&parsed, (1, 1), &mut b2, 1_000_000).unwrap();
        assert_eq!(b1.bufs, b2.bufs);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_function("not a kernel").is_err());
        assert!(parse_function("kernel @k() {\nentry:\n  %0 = bogus 1\n  ret\n}").is_err());
        assert!(parse_function("kernel @k() {\nentry:\n  br nowhere\n  ret\n}").is_err());
    }
}
