//! Textual IR dumping (LLVM-flavoured, for debugging and golden tests).

use std::fmt::Write;

use super::function::Function;
use super::inst::{InstId, Op};
use super::module::Module;
use super::value::Value;

pub fn print_value(v: Value) -> String {
    match v {
        Value::Arg(i) => format!("%arg{i}"),
        Value::Inst(InstId(i)) => format!("%{i}"),
        Value::ImmI(x) => format!("{x}"),
        Value::ImmF(bits) => format!("{:?}", f32::from_bits(bits)),
        Value::GlobalId(d) => format!("@gid.{d}"),
        Value::GlobalSize(d) => format!("@gsz.{d}"),
    }
}

pub fn print_function(f: &Function) -> String {
    let mut s = String::new();
    let params: Vec<String> = f
        .params
        .iter()
        .map(|p| format!("{:?} %{}", p.ty, p.name))
        .collect();
    let _ = writeln!(s, "kernel @{}({}) {{", f.name, params.join(", "));
    // labels must be unique for the text to round-trip through the
    // parser; structured construction can reuse names (nested "if.then")
    let mut name_count = std::collections::HashMap::new();
    for bb in f.block_ids() {
        *name_count.entry(f.block(bb).name.clone()).or_insert(0usize) += 1;
    }
    let label = |bb: crate::ir::BlockId| -> String {
        let n = &f.block(bb).name;
        if name_count.get(n).copied().unwrap_or(0) > 1 {
            format!("{n}.b{}", bb.0)
        } else {
            n.clone()
        }
    };
    for bb in f.block_ids() {
        let blk = f.block(bb);
        if blk.insts.is_empty() && blk.preds.is_empty() && bb != f.entry {
            continue; // detached block
        }
        let preds: Vec<String> = blk.preds.iter().map(|&p| label(p)).collect();
        let _ = writeln!(
            s,
            "{}:{}{}",
            label(bb),
            if preds.is_empty() {
                String::new()
            } else {
                format!("    ; preds: {}", preds.join(", "))
            },
            if blk.unroll > 1 {
                format!("  ; unroll={}", blk.unroll)
            } else {
                String::new()
            }
        );
        for &iid in &blk.insts {
            let inst = f.inst(iid);
            if inst.is_nop() {
                continue;
            }
            let args: Vec<String> = inst.args().iter().map(|&a| print_value(a)).collect();
            let pred_str = match inst.op {
                Op::ICmp(p) | Op::FCmp(p) => format!(".{p:?}").to_lowercase(),
                _ => String::new(),
            };
            let rhs = match inst.op {
                Op::Br => format!("br {}", label(blk.succs[0])),
                Op::CondBr => format!(
                    "condbr {}, {}, {}",
                    args[0],
                    label(blk.succs[0]),
                    label(blk.succs[1])
                ),
                _ => format!("{}{} {}", inst.op.mnemonic(), pred_str, args.join(", ")),
            };
            if inst.op.is_terminator() || inst.op == Op::Store {
                let _ = writeln!(s, "  {rhs}");
            } else {
                let _ = writeln!(s, "  %{} = {rhs}", iid.0);
            }
        }
    }
    let _ = writeln!(s, "}}");
    s
}

pub fn print_module(m: &Module) -> String {
    let mut s = format!(
        "; module {} precise_aa={} aa_stale={} allocas_lowered={}\n",
        m.name,
        m.precise_aa(),
        m.aa_stale(),
        m.allocas_lowered()
    );
    for k in &m.kernels {
        s.push_str(&print_function(k));
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{AddrSpace, KernelBuilder, Ty};

    #[test]
    fn prints_loop_kernel() {
        let mut b = KernelBuilder::new("k", &[("a", Ty::Ptr(AddrSpace::Global))]);
        let n = b.i(4);
        b.for_loop("i", b.i(0), n, 1, |b, iv| {
            let v = b.load(b.param(0), iv);
            b.store(b.param(0), iv, v);
        });
        let f = b.finish();
        let text = print_function(&f);
        assert!(text.contains("kernel @k"));
        assert!(text.contains("phi"));
        assert!(text.contains("condbr"));
        assert!(text.contains("load"));
    }
}
