//! Functions: instruction arena + block list + utilities shared by passes.

use std::collections::HashMap;

use super::block::{Block, BlockId};
use super::inst::{Inst, InstId, Op};
use super::types::Ty;
use super::value::Value;

/// A kernel parameter. Pointer parameters are the global buffers; the
/// paper's aliasing question ("can two buffer arguments overlap?") is
/// asked about exactly these.
#[derive(Debug, Clone)]
pub struct Param {
    pub name: String,
    pub ty: Ty,
    /// OpenCL 2.0 semantics: overlapping buffers would be a data race
    /// (undefined behaviour), so a precise AA may treat distinct pointer
    /// params as non-aliasing. BasicAA does not exploit this — that gap is
    /// the paper's store-sinking story.
    pub noalias_by_spec: bool,
}

/// A GPU kernel in SSA form.
#[derive(Debug, Clone)]
pub struct Function {
    pub name: String,
    pub params: Vec<Param>,
    pub blocks: Vec<Block>,
    pub insts: Vec<Inst>,
    pub entry: BlockId,
}

impl Function {
    pub fn new(name: impl Into<String>) -> Function {
        Function {
            name: name.into(),
            params: Vec::new(),
            blocks: Vec::new(),
            insts: Vec::new(),
            entry: BlockId(0),
        }
    }

    // ---- arena ----

    pub fn inst(&self, id: InstId) -> &Inst {
        &self.insts[id.0 as usize]
    }
    pub fn inst_mut(&mut self, id: InstId) -> &mut Inst {
        &mut self.insts[id.0 as usize]
    }
    pub fn add_inst(&mut self, inst: Inst) -> InstId {
        let id = InstId(self.insts.len() as u32);
        self.insts.push(inst);
        id
    }
    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[id.0 as usize]
    }
    pub fn block_mut(&mut self, id: BlockId) -> &mut Block {
        &mut self.blocks[id.0 as usize]
    }
    pub fn add_block(&mut self, b: Block) -> BlockId {
        let id = BlockId(self.blocks.len() as u32);
        self.blocks.push(b);
        id
    }
    pub fn block_ids(&self) -> impl Iterator<Item = BlockId> {
        (0..self.blocks.len() as u32).map(BlockId)
    }

    // ---- instruction placement ----

    /// Append `inst` to `bb` (before the terminator if one exists).
    pub fn insert_inst(&mut self, bb: BlockId, inst: Inst) -> InstId {
        let id = self.add_inst(inst);
        let blk = self.block_mut(bb);
        blk.insts.push(id);
        id
    }

    /// Insert before the terminator of `bb`.
    pub fn insert_before_term(&mut self, bb: BlockId, inst: Inst) -> InstId {
        let id = self.add_inst(inst);
        let blk = &mut self.blocks[bb.0 as usize];
        let pos = blk.insts.len().saturating_sub(1);
        blk.insts.insert(pos, id);
        id
    }

    /// Insert at the top of `bb`, after any phis.
    pub fn insert_after_phis(&mut self, bb: BlockId, inst: Inst) -> InstId {
        let id = self.add_inst(inst);
        let n_phis = self.blocks[bb.0 as usize]
            .insts
            .iter()
            .take_while(|&&i| self.insts[i.0 as usize].op == Op::Phi)
            .count();
        self.blocks[bb.0 as usize].insts.insert(n_phis, id);
        id
    }

    /// Mark an instruction dead and unlink it from its block.
    pub fn remove_inst(&mut self, bb: BlockId, id: InstId) {
        self.blocks[bb.0 as usize].insts.retain(|&i| i != id);
        self.insts[id.0 as usize] = Inst::nop();
    }

    /// Mark dead without unlinking (caller rebuilds the list).
    pub fn kill_inst(&mut self, id: InstId) {
        self.insts[id.0 as usize] = Inst::nop();
    }

    pub fn terminator(&self, bb: BlockId) -> Option<InstId> {
        let blk = self.block(bb);
        blk.insts.last().copied().filter(|&i| self.inst(i).op.is_terminator())
    }

    // ---- use querying / rewriting ----

    /// Replace every use of `from` with `to`, everywhere.
    pub fn replace_all_uses(&mut self, from: Value, to: Value) {
        for inst in &mut self.insts {
            if inst.is_nop() {
                continue;
            }
            for a in inst.args_mut() {
                if *a == from {
                    *a = to;
                }
            }
        }
    }

    /// Count uses of an instruction's result.
    pub fn num_uses(&self, id: InstId) -> usize {
        let v = Value::Inst(id);
        self.insts
            .iter()
            .filter(|i| !i.is_nop())
            .map(|i| i.args().iter().filter(|&&a| a == v).count())
            .sum()
    }

    /// Map from instruction to its containing block (O(insts)).
    pub fn inst_blocks(&self) -> HashMap<InstId, BlockId> {
        let mut m = HashMap::with_capacity(self.insts.len());
        for bb in self.block_ids() {
            for &i in &self.block(bb).insts {
                m.insert(i, bb);
            }
        }
        m
    }

    /// Position of each instruction within its block (for dominance checks).
    pub fn inst_positions(&self) -> HashMap<InstId, (BlockId, usize)> {
        let mut m = HashMap::with_capacity(self.insts.len());
        for bb in self.block_ids() {
            for (k, &i) in self.block(bb).insts.iter().enumerate() {
                m.insert(i, (bb, k));
            }
        }
        m
    }

    // ---- CFG edits ----

    /// Redirect the CFG edge `from -> old_to` to `from -> new_to`,
    /// updating succ/pred lists. Phi operands of `old_to` for this pred
    /// are dropped; `new_to` gains `from` as a pred (callers must fix phis
    /// in `new_to` themselves if it has any).
    pub fn redirect_edge(&mut self, from: BlockId, old_to: BlockId, new_to: BlockId) {
        for s in &mut self.blocks[from.0 as usize].succs {
            if *s == old_to {
                *s = new_to;
            }
        }
        // drop pred + aligned phi operands in old_to
        if let Some(pi) = self.block(old_to).pred_index(from) {
            self.blocks[old_to.0 as usize].preds.remove(pi);
            let phi_ids: Vec<InstId> = self
                .block(old_to)
                .insts
                .iter()
                .copied()
                .filter(|&i| self.inst(i).op == Op::Phi)
                .collect();
            for p in phi_ids {
                self.inst_mut(p).remove_arg(pi);
            }
        }
        self.blocks[new_to.0 as usize].preds.push(from);
    }

    /// Total live (non-nop) instruction count.
    pub fn num_live_insts(&self) -> usize {
        self.insts.iter().filter(|i| !i.is_nop()).count()
    }

    /// Reverse postorder over the CFG from entry.
    pub fn rpo(&self) -> Vec<BlockId> {
        let mut visited = vec![false; self.blocks.len()];
        let mut post = Vec::with_capacity(self.blocks.len());
        // iterative DFS
        let mut stack: Vec<(BlockId, usize)> = vec![(self.entry, 0)];
        visited[self.entry.0 as usize] = true;
        while let Some(&mut (bb, ref mut i)) = stack.last_mut() {
            let succs = &self.block(bb).succs;
            if *i < succs.len() {
                let s = succs[*i];
                *i += 1;
                if !visited[s.0 as usize] {
                    visited[s.0 as usize] = true;
                    stack.push((s, 0));
                }
            } else {
                post.push(bb);
                stack.pop();
            }
        }
        post.reverse();
        post
    }

    /// Rebuild pred lists from succ lists (sanity tool used by tests).
    pub fn recompute_preds(&mut self) {
        let n = self.blocks.len();
        let mut preds: Vec<Vec<BlockId>> = vec![Vec::new(); n];
        for bb in self.block_ids() {
            for &s in &self.block(bb).succs {
                preds[s.0 as usize].push(bb);
            }
        }
        for (i, p) in preds.into_iter().enumerate() {
            self.blocks[i].preds = p;
        }
    }
}
