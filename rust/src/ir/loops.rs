//! Natural-loop detection and the loop forest.
//!
//! Every loop-oriented pass (`licm`, `loop-reduce`, `loop-unroll`,
//! `loop-unswitch`, `loop-extract-single`) and the cost model consume this.

use std::collections::HashSet;

use super::block::BlockId;
use super::dom::DomTree;
use super::function::Function;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Loop {
    pub header: BlockId,
    /// Back-edge sources (typically one latch in our structured kernels).
    pub latches: Vec<BlockId>,
    /// All blocks in the loop body (including header).
    pub blocks: Vec<BlockId>,
    /// The unique block that jumps into the header from outside, if the
    /// loop is in canonical form (our builder always emits one).
    pub preheader: Option<BlockId>,
    /// Blocks outside the loop targeted from inside (loop exits).
    pub exits: Vec<BlockId>,
    /// Parent loop index in the forest (None = top level).
    pub parent: Option<usize>,
    /// Nesting depth (1 = outermost).
    pub depth: u32,
}

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LoopForest {
    pub loops: Vec<Loop>,
}

impl LoopForest {
    pub fn compute(f: &Function, dt: &DomTree) -> LoopForest {
        // find back edges: s -> h where h dominates s
        let mut loops: Vec<Loop> = Vec::new();
        let mut header_of: Vec<Option<usize>> = vec![None; f.blocks.len()];
        for bb in f.block_ids() {
            if !dt.is_reachable(bb) {
                continue;
            }
            for &s in &f.block(bb).succs {
                if dt.dominates(s, bb) {
                    // back edge bb -> s
                    let idx = match header_of[s.0 as usize] {
                        Some(i) => i,
                        None => {
                            loops.push(Loop {
                                header: s,
                                latches: Vec::new(),
                                blocks: Vec::new(),
                                preheader: None,
                                exits: Vec::new(),
                                parent: None,
                                depth: 0,
                            });
                            header_of[s.0 as usize] = Some(loops.len() - 1);
                            loops.len() - 1
                        }
                    };
                    loops[idx].latches.push(bb);
                }
            }
        }
        // body discovery: reverse reachability from the latches up to the
        // header (classic natural-loop body construction)
        for l in &mut loops {
            let mut body: HashSet<BlockId> = HashSet::new();
            body.insert(l.header);
            let mut stack: Vec<BlockId> =
                l.latches.iter().copied().filter(|&b| b != l.header).collect();
            while let Some(b) = stack.pop() {
                if body.insert(b) {
                    for &p in &f.block(b).preds {
                        if !body.contains(&p) {
                            stack.push(p);
                        }
                    }
                }
            }
            let mut blocks: Vec<BlockId> = body.iter().copied().collect();
            blocks.sort();
            l.blocks = blocks;
            // preheader: unique out-of-loop pred of header
            let outside: Vec<BlockId> = f
                .block(l.header)
                .preds
                .iter()
                .copied()
                .filter(|p| !body.contains(p))
                .collect();
            if outside.len() == 1 {
                l.preheader = Some(outside[0]);
            }
            // exits
            let mut exits = Vec::new();
            for &b in &l.blocks {
                for &s in &f.block(b).succs {
                    if !body.contains(&s) && !exits.contains(&s) {
                        exits.push(s);
                    }
                }
            }
            l.exits = exits;
        }
        // nesting: loop A is parent of B if A contains B's header and A != B
        let mut forest = LoopForest { loops };
        let n = forest.loops.len();
        for i in 0..n {
            let mut best: Option<usize> = None;
            for j in 0..n {
                if i == j {
                    continue;
                }
                if forest.loops[j].blocks.contains(&forest.loops[i].header)
                    && forest.loops[j].header != forest.loops[i].header
                {
                    // smallest containing loop
                    best = match best {
                        None => Some(j),
                        Some(b) if forest.loops[j].blocks.len() < forest.loops[b].blocks.len() => {
                            Some(j)
                        }
                        b => b,
                    };
                }
            }
            forest.loops[i].parent = best;
        }
        for i in 0..n {
            let mut d = 1;
            let mut p = forest.loops[i].parent;
            while let Some(pi) = p {
                d += 1;
                p = forest.loops[pi].parent;
            }
            forest.loops[i].depth = d;
        }
        forest
    }

    pub fn contains(&self, li: usize, b: BlockId) -> bool {
        self.loops[li].blocks.contains(&b)
    }

    /// Innermost loop containing block `b`, if any.
    pub fn innermost_containing(&self, b: BlockId) -> Option<usize> {
        self.loops
            .iter()
            .enumerate()
            .filter(|(_, l)| l.blocks.contains(&b))
            .max_by_key(|(_, l)| l.depth)
            .map(|(i, _)| i)
    }

    /// Loops ordered innermost-first (deepest depth first).
    pub fn innermost_first(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.loops.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(self.loops[i].depth));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Block, Function};

    /// entry -> ph -> header <-> body(latch) ; header -> exit
    fn single_loop() -> Function {
        let mut f = Function::new("l");
        for n in ["entry", "ph", "header", "body", "exit"] {
            f.add_block(Block::new(n));
        }
        let b = |i| BlockId(i);
        f.block_mut(b(0)).succs = vec![b(1)];
        f.block_mut(b(1)).succs = vec![b(2)];
        f.block_mut(b(2)).succs = vec![b(3), b(4)];
        f.block_mut(b(3)).succs = vec![b(2)];
        f.recompute_preds();
        f
    }

    #[test]
    fn finds_single_loop() {
        let f = single_loop();
        let dt = DomTree::compute(&f);
        let lf = LoopForest::compute(&f, &dt);
        assert_eq!(lf.loops.len(), 1);
        let l = &lf.loops[0];
        assert_eq!(l.header, BlockId(2));
        assert_eq!(l.latches, vec![BlockId(3)]);
        assert_eq!(l.preheader, Some(BlockId(1)));
        assert_eq!(l.exits, vec![BlockId(4)]);
        assert_eq!(l.depth, 1);
    }

    /// Two-level nest: outer header 1, inner loop {3,4}.
    fn nested() -> Function {
        let mut f = Function::new("n");
        for n in ["entry", "oh", "iph", "ih", "ibody", "olatch", "exit"] {
            f.add_block(Block::new(n));
        }
        let b = |i| BlockId(i);
        f.block_mut(b(0)).succs = vec![b(1)];
        f.block_mut(b(1)).succs = vec![b(2), b(6)];
        f.block_mut(b(2)).succs = vec![b(3)];
        f.block_mut(b(3)).succs = vec![b(4), b(5)];
        f.block_mut(b(4)).succs = vec![b(3)];
        f.block_mut(b(5)).succs = vec![b(1)];
        f.recompute_preds();
        f
    }

    #[test]
    fn finds_nested_loops() {
        let f = nested();
        let dt = DomTree::compute(&f);
        let lf = LoopForest::compute(&f, &dt);
        assert_eq!(lf.loops.len(), 2);
        let inner = lf
            .loops
            .iter()
            .find(|l| l.header == BlockId(3))
            .expect("inner loop");
        let outer = lf
            .loops
            .iter()
            .find(|l| l.header == BlockId(1))
            .expect("outer loop");
        assert_eq!(inner.depth, 2);
        assert_eq!(outer.depth, 1);
        assert!(outer.blocks.contains(&BlockId(3)));
        assert_eq!(inner.preheader, Some(BlockId(2)));
        let inner_idx = lf.loops.iter().position(|l| l.header == BlockId(3)).unwrap();
        let outer_idx = lf.loops.iter().position(|l| l.header == BlockId(1)).unwrap();
        assert_eq!(lf.loops[inner_idx].parent, Some(outer_idx));
        assert_eq!(lf.innermost_containing(BlockId(4)), Some(inner_idx));
    }
}
