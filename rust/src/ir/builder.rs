//! Structured kernel construction.
//!
//! `KernelBuilder` is the "frontend": the benchmark suite uses it to build
//! each PolyBench/GPU kernel the way Clang's OpenCL frontend would — naive
//! per-access address chains (`sext`+`shl`+`ptradd`, the 5-instruction
//! pattern of the paper's Fig. 6), canonical loop form (dedicated
//! preheader, header phi, latch), and guard conditionals.

use super::block::{Block, BlockId};
use super::function::{Function, Param};
use super::inst::{CmpPred, Inst, InstId, Op};
use super::types::{AddrSpace, Ty};
use super::value::Value;

pub struct KernelBuilder {
    pub f: Function,
    cur: BlockId,
}

impl KernelBuilder {
    /// Create a kernel. Pointer params default to `noalias_by_spec = true`
    /// (OpenCL 2.0: overlap would be a data race, hence UB).
    pub fn new(name: &str, params: &[(&str, Ty)]) -> KernelBuilder {
        let mut f = Function::new(name);
        for (pname, ty) in params {
            f.params.push(Param {
                name: pname.to_string(),
                ty: *ty,
                noalias_by_spec: ty.is_ptr(),
            });
        }
        let entry = f.add_block(Block::new("entry"));
        f.entry = entry;
        KernelBuilder { f, cur: entry }
    }

    pub fn finish(mut self) -> Function {
        self.emit(Inst::new(Op::Ret, Ty::Void, &[]));
        self.f
    }

    pub fn param(&self, i: usize) -> Value {
        assert!(i < self.f.params.len());
        Value::Arg(i as u16)
    }

    pub fn cur_block(&self) -> BlockId {
        self.cur
    }

    fn emit(&mut self, inst: Inst) -> Value {
        let id = self.f.insert_inst(self.cur, inst);
        Value::Inst(id)
    }

    // ---- scalar ops ----

    pub fn i(&self, v: i64) -> Value {
        Value::ImmI(v)
    }
    pub fn fc(&self, v: f32) -> Value {
        Value::imm_f(v)
    }
    pub fn gid(&self, dim: u8) -> Value {
        Value::GlobalId(dim)
    }

    pub fn bin(&mut self, op: Op, ty: Ty, a: Value, b: Value) -> Value {
        self.emit(Inst::new(op, ty, &[a, b]))
    }
    pub fn add(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::Add, Ty::I32, a, b)
    }
    pub fn sub(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::Sub, Ty::I32, a, b)
    }
    pub fn mul(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::Mul, Ty::I32, a, b)
    }
    pub fn fadd(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::FAdd, Ty::F32, a, b)
    }
    pub fn fsub(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::FSub, Ty::F32, a, b)
    }
    pub fn fmul(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::FMul, Ty::F32, a, b)
    }
    pub fn fdiv(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::FDiv, Ty::F32, a, b)
    }
    pub fn fsqrt(&mut self, a: Value) -> Value {
        self.emit(Inst::new(Op::FSqrt, Ty::F32, &[a]))
    }
    pub fn fexp(&mut self, a: Value) -> Value {
        self.emit(Inst::new(Op::FExp, Ty::F32, &[a]))
    }
    pub fn icmp(&mut self, p: CmpPred, a: Value, b: Value) -> Value {
        self.emit(Inst::new(Op::ICmp(p), Ty::I1, &[a, b]))
    }
    pub fn fcmp(&mut self, p: CmpPred, a: Value, b: Value) -> Value {
        self.emit(Inst::new(Op::FCmp(p), Ty::I1, &[a, b]))
    }
    pub fn and(&mut self, a: Value, b: Value) -> Value {
        self.bin(Op::And, Ty::I1, a, b)
    }
    pub fn select(&mut self, c: Value, t: Value, e: Value) -> Value {
        self.emit(Inst::new(Op::Select, Ty::F32, &[c, t, e]))
    }
    pub fn sitofp(&mut self, a: Value) -> Value {
        self.emit(Inst::new(Op::SiToFp, Ty::F32, &[a]))
    }
    pub fn fptosi(&mut self, a: Value) -> Value {
        self.emit(Inst::new(Op::FpToSi, Ty::I32, &[a]))
    }

    // ---- addressing + memory (the Fig. 6 naive pattern) ----

    /// Compute `&base[idx]` the way the OpenCL frontend does: sign-extend
    /// the i32 element index, shift to a byte offset, pointer-add.
    pub fn addr(&mut self, base: Value, idx: Value) -> Value {
        let ext = self.emit(Inst::new(Op::Sext, Ty::I64, &[idx]));
        let off = self.emit(Inst::new(Op::Shl, Ty::I64, &[ext, Value::ImmI(2)]));
        self.emit(Inst::new(Op::PtrAdd, Ty::Ptr(AddrSpace::Global), &[base, off]))
    }

    /// `base[idx]` load.
    pub fn load(&mut self, base: Value, idx: Value) -> Value {
        let p = self.addr(base, idx);
        self.emit(Inst::new(Op::Load, Ty::F32, &[p]))
    }

    /// `base[idx] = val` store.
    pub fn store(&mut self, base: Value, idx: Value, val: Value) {
        let p = self.addr(base, idx);
        self.emit(Inst::new(Op::Store, Ty::Void, &[p, val]));
    }

    /// `atomic_add(&base[idx], val)`, returning the old value.
    pub fn atom_add(&mut self, base: Value, idx: Value, val: Value) -> Value {
        let p = self.addr(base, idx);
        self.emit(Inst::new(Op::AtomAdd, Ty::F32, &[p, val]))
    }

    /// `atomic_max(&base[idx], val)`, returning the old value.
    pub fn atom_max(&mut self, base: Value, idx: Value, val: Value) -> Value {
        let p = self.addr(base, idx);
        self.emit(Inst::new(Op::AtomMax, Ty::F32, &[p, val]))
    }

    // ---- structured control flow ----

    fn seal_with_br(&mut self, to: BlockId) {
        self.emit(Inst::new(Op::Br, Ty::Void, &[]));
        self.f.block_mut(self.cur).succs.push(to);
        let cur = self.cur;
        self.f.block_mut(to).preds.push(cur);
    }

    fn seal_with_condbr(&mut self, cond: Value, t: BlockId, e: BlockId) {
        self.emit(Inst::new(Op::CondBr, Ty::Void, &[cond]));
        let cur = self.cur;
        self.f.block_mut(cur).succs = vec![t, e];
        self.f.block_mut(t).preds.push(cur);
        self.f.block_mut(e).preds.push(cur);
    }

    /// Canonical counted loop `for (iv = start; iv < end; iv += step)`.
    /// Emits preheader → header(phi, cmp, condbr) → body… → latch → header,
    /// leaves the builder positioned in the exit block. The body closure
    /// receives the induction variable and may itself open nested loops or
    /// conditionals. Returns the header block id (unroll hints attach
    /// there).
    pub fn for_loop(
        &mut self,
        name: &str,
        start: Value,
        end: Value,
        step: i64,
        body: impl FnOnce(&mut KernelBuilder, Value),
    ) -> BlockId {
        let ph = self.f.add_block(Block::new(format!("{name}.ph")));
        let header = self.f.add_block(Block::new(format!("{name}.hd")));
        let body_bb = self.f.add_block(Block::new(format!("{name}.body")));
        let latch = self.f.add_block(Block::new(format!("{name}.latch")));
        let exit = self.f.add_block(Block::new(format!("{name}.exit")));

        self.seal_with_br(ph);
        self.cur = ph;
        self.seal_with_br(header);

        // header: iv = phi [start, ph], [iv.next, latch]; cmp; condbr
        self.cur = header;
        let phi_id = self.f.insert_inst(header, Inst::new(Op::Phi, Ty::I32, &[start]));
        let iv = Value::Inst(phi_id);
        let cond = self.icmp(CmpPred::Lt, iv, end);
        self.seal_with_condbr(cond, body_bb, exit);

        // body
        self.cur = body_bb;
        body(self, iv);
        self.seal_with_br(latch);

        // latch: iv.next = iv + step; br header
        self.cur = latch;
        let ivn = self.add(iv, Value::ImmI(step));
        self.emit(Inst::new(Op::Br, Ty::Void, &[]));
        self.f.block_mut(latch).succs.push(header);
        self.f.block_mut(header).preds.push(latch);
        self.f.inst_mut(phi_id).push_arg(ivn);

        self.cur = exit;
        header
    }

    /// Counted loop that additionally threads a float accumulator through
    /// the iterations (SSA form with a header phi). Returns the final
    /// accumulator value, usable in the exit block. This is the form the
    /// *optimized* kernels take; baseline PolyBench kernels accumulate
    /// through memory instead and rely on `licm` to reach this form.
    pub fn for_loop_acc(
        &mut self,
        name: &str,
        start: Value,
        end: Value,
        step: i64,
        acc_init: Value,
        body: impl FnOnce(&mut KernelBuilder, Value, Value) -> Value,
    ) -> (BlockId, Value) {
        let ph = self.f.add_block(Block::new(format!("{name}.ph")));
        let header = self.f.add_block(Block::new(format!("{name}.hd")));
        let body_bb = self.f.add_block(Block::new(format!("{name}.body")));
        let latch = self.f.add_block(Block::new(format!("{name}.latch")));
        let exit = self.f.add_block(Block::new(format!("{name}.exit")));

        self.seal_with_br(ph);
        self.cur = ph;
        self.seal_with_br(header);

        self.cur = header;
        let phi_id = self.f.insert_inst(header, Inst::new(Op::Phi, Ty::I32, &[start]));
        let acc_phi = self.f.insert_inst(header, Inst::new(Op::Phi, Ty::F32, &[acc_init]));
        let iv = Value::Inst(phi_id);
        let acc = Value::Inst(acc_phi);
        let cond = self.icmp(CmpPred::Lt, iv, end);
        self.seal_with_condbr(cond, body_bb, exit);

        self.cur = body_bb;
        let acc_next = body(self, iv, acc);
        self.seal_with_br(latch);

        self.cur = latch;
        let ivn = self.add(iv, Value::ImmI(step));
        self.emit(Inst::new(Op::Br, Ty::Void, &[]));
        self.f.block_mut(latch).succs.push(header);
        self.f.block_mut(header).preds.push(latch);
        self.f.inst_mut(phi_id).push_arg(ivn);
        self.f.inst_mut(acc_phi).push_arg(acc_next);

        self.cur = exit;
        (header, acc)
    }

    /// Guard conditional: `if (cond) { body }` with a join block.
    pub fn if_then(&mut self, cond: Value, body: impl FnOnce(&mut KernelBuilder)) {
        let then_bb = self.f.add_block(Block::new("if.then"));
        let join = self.f.add_block(Block::new("if.join"));
        self.seal_with_condbr(cond, then_bb, join);
        self.cur = then_bb;
        body(self);
        self.seal_with_br(join);
        self.cur = join;
    }

    /// `if (cond) { t } else { e }` producing a merged float value via phi.
    pub fn if_then_else_val(
        &mut self,
        cond: Value,
        t: impl FnOnce(&mut KernelBuilder) -> Value,
        e: impl FnOnce(&mut KernelBuilder) -> Value,
    ) -> Value {
        let then_bb = self.f.add_block(Block::new("ite.then"));
        let else_bb = self.f.add_block(Block::new("ite.else"));
        let join = self.f.add_block(Block::new("ite.join"));
        self.seal_with_condbr(cond, then_bb, else_bb);
        self.cur = then_bb;
        let tv = t(self);
        self.seal_with_br(join);
        self.cur = else_bb;
        let ev = e(self);
        self.seal_with_br(join);
        self.cur = join;
        // phi aligned with preds: [then_bb, else_bb] in push order
        let phi = self.f.insert_inst(join, Inst::new(Op::Phi, Ty::F32, &[tv, ev]));
        Value::Inst(phi)
    }

    /// Attach an unroll hint to a loop header (frontend metadata).
    pub fn set_unroll(&mut self, header: BlockId, factor: u8) {
        self.f.block_mut(header).unroll = factor;
    }

    /// Fetch the instruction id behind a value (test convenience).
    pub fn inst_of(&self, v: Value) -> InstId {
        v.as_inst().expect("value is an instruction")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verifier::verify_function;

    /// Simple saxpy-like kernel: y[gid] = a*x[gid] + y[gid] built with the
    /// naive addressing pattern.
    fn saxpy() -> Function {
        let mut b = KernelBuilder::new(
            "saxpy",
            &[
                ("x", Ty::Ptr(AddrSpace::Global)),
                ("y", Ty::Ptr(AddrSpace::Global)),
            ],
        );
        let gid = b.gid(0);
        let xv = b.load(b.param(0), gid);
        let t = b.fmul(xv, b.fc(2.0));
        let yv = b.load(b.param(1), gid);
        let s = b.fadd(t, yv);
        b.store(b.param(1), gid, s);
        b.finish()
    }

    #[test]
    fn saxpy_verifies() {
        let f = saxpy();
        verify_function(&f).expect("verifier clean");
        assert!(f.num_live_insts() > 8);
    }

    #[test]
    fn loop_kernel_has_canonical_loop() {
        let mut b = KernelBuilder::new("k", &[("a", Ty::Ptr(AddrSpace::Global))]);
        let n = b.i(16);
        b.for_loop("i", b.i(0), n, 1, |b, iv| {
            let v = b.load(b.param(0), iv);
            let v2 = b.fadd(v, b.fc(1.0));
            b.store(b.param(0), iv, v2);
        });
        let f = b.finish();
        verify_function(&f).expect("verifier clean");
        let (_dt, lf) = crate::passes::analyses::analyses_of(&f);
        assert_eq!(lf.loops.len(), 1);
        assert!(lf.loops[0].preheader.is_some());
        assert_eq!(lf.loops[0].latches.len(), 1);
    }

    #[test]
    fn acc_loop_threads_accumulator() {
        let mut b = KernelBuilder::new("dot", &[("a", Ty::Ptr(AddrSpace::Global))]);
        let n = b.i(8);
        let (_h, acc) = b.for_loop_acc("i", b.i(0), n, 1, b.fc(0.0), |b, iv, acc| {
            let v = b.load(b.param(0), iv);
            b.fadd(acc, v)
        });
        b.store(b.param(0), b.i(0), acc);
        let f = b.finish();
        verify_function(&f).expect("verifier clean");
    }

    #[test]
    fn if_then_else_val_merges() {
        let mut b = KernelBuilder::new("sel", &[("a", Ty::Ptr(AddrSpace::Global))]);
        let c = b.icmp(CmpPred::Lt, b.gid(0), b.i(4));
        let v = b.if_then_else_val(c, |b| b.fc(1.0), |b| b.fc(2.0));
        b.store(b.param(0), b.gid(0), v);
        let f = b.finish();
        verify_function(&f).expect("verifier clean");
    }
}
