//! Dominator tree (Cooper–Harvey–Kennedy iterative algorithm).

use super::block::BlockId;
use super::function::Function;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DomTree {
    /// Immediate dominator per block (entry's idom is itself). `None` for
    /// unreachable blocks.
    pub idom: Vec<Option<BlockId>>,
    /// Reverse postorder used to compute the tree.
    pub rpo: Vec<BlockId>,
    /// RPO position per block (also exposed for analyses that need a
    /// topological order consistent with the tree).
    pub rpo_index: Vec<usize>,
}

impl DomTree {
    pub fn compute(f: &Function) -> DomTree {
        let n = f.blocks.len();
        let rpo = f.rpo();
        let mut rpo_index = vec![usize::MAX; n];
        for (i, &b) in rpo.iter().enumerate() {
            rpo_index[b.0 as usize] = i;
        }
        let mut idom: Vec<Option<BlockId>> = vec![None; n];
        idom[f.entry.0 as usize] = Some(f.entry);
        let mut changed = true;
        while changed {
            changed = false;
            for &b in rpo.iter().skip(1) {
                // first processed predecessor
                let mut new_idom: Option<BlockId> = None;
                for &p in &f.block(b).preds {
                    if idom[p.0 as usize].is_none() {
                        continue;
                    }
                    new_idom = Some(match new_idom {
                        None => p,
                        Some(cur) => Self::intersect(&idom, &rpo_index, p, cur),
                    });
                }
                if let Some(ni) = new_idom {
                    if idom[b.0 as usize] != Some(ni) {
                        idom[b.0 as usize] = Some(ni);
                        changed = true;
                    }
                }
            }
        }
        DomTree {
            idom,
            rpo,
            rpo_index,
        }
    }

    fn intersect(
        idom: &[Option<BlockId>],
        rpo_index: &[usize],
        mut a: BlockId,
        mut b: BlockId,
    ) -> BlockId {
        while a != b {
            while rpo_index[a.0 as usize] > rpo_index[b.0 as usize] {
                a = idom[a.0 as usize].expect("reachable");
            }
            while rpo_index[b.0 as usize] > rpo_index[a.0 as usize] {
                b = idom[b.0 as usize].expect("reachable");
            }
        }
        a
    }

    /// Does `a` dominate `b`? (reflexive)
    pub fn dominates(&self, a: BlockId, b: BlockId) -> bool {
        let mut cur = b;
        loop {
            if cur == a {
                return true;
            }
            match self.idom[cur.0 as usize] {
                Some(i) if i != cur => cur = i,
                _ => return false,
            }
        }
    }

    pub fn is_reachable(&self, b: BlockId) -> bool {
        self.idom[b.0 as usize].is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Block, Function};

    /// Diamond: 0 -> {1,2} -> 3.
    fn diamond() -> Function {
        let mut f = Function::new("d");
        for n in ["e", "t", "f", "m"] {
            f.add_block(Block::new(n));
        }
        let b = |i| BlockId(i);
        f.block_mut(b(0)).succs = vec![b(1), b(2)];
        f.block_mut(b(1)).succs = vec![b(3)];
        f.block_mut(b(2)).succs = vec![b(3)];
        f.recompute_preds();
        f
    }

    #[test]
    fn diamond_idoms() {
        let f = diamond();
        let dt = DomTree::compute(&f);
        assert_eq!(dt.idom[1], Some(BlockId(0)));
        assert_eq!(dt.idom[2], Some(BlockId(0)));
        assert_eq!(dt.idom[3], Some(BlockId(0)));
        assert!(dt.dominates(BlockId(0), BlockId(3)));
        assert!(!dt.dominates(BlockId(1), BlockId(3)));
        assert!(dt.dominates(BlockId(3), BlockId(3)));
    }

    #[test]
    fn unreachable_block() {
        let mut f = diamond();
        f.add_block(Block::new("dead"));
        let dt = DomTree::compute(&f);
        assert!(!dt.is_reachable(BlockId(4)));
    }
}
