//! SSA values: arguments, instruction results, immediates, SIMT identity.

use super::inst::InstId;

/// A use of an SSA value. `Copy` so instruction operand arrays stay flat.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Value {
    /// The n-th kernel parameter.
    Arg(u16),
    /// Result of an instruction.
    Inst(InstId),
    /// Integer immediate (i32/i64 contexts).
    ImmI(i64),
    /// f32 immediate, stored as bits so `Value` stays `Eq + Hash`.
    ImmF(u32),
    /// `get_global_id(dim)` — the SIMT lane coordinate. Loop-invariant and
    /// pure by construction, like a read-only special register in PTX
    /// (`%tid`/`%ctaid` folded together).
    GlobalId(u8),
    /// `get_global_size(dim)`.
    GlobalSize(u8),
}

impl Value {
    pub fn imm_f(f: f32) -> Value {
        Value::ImmF(f.to_bits())
    }
    pub fn as_imm_i(self) -> Option<i64> {
        match self {
            Value::ImmI(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_imm_f(self) -> Option<f32> {
        match self {
            Value::ImmF(bits) => Some(f32::from_bits(bits)),
            _ => None,
        }
    }
    /// True if the value is a constant or thread-identity (never varies
    /// within a thread; trivially loop-invariant).
    pub fn is_trivially_invariant(self) -> bool {
        !matches!(self, Value::Inst(_))
    }
    pub fn as_inst(self) -> Option<InstId> {
        match self {
            Value::Inst(id) => Some(id),
            _ => None,
        }
    }
}
