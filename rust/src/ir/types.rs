//! Scalar and pointer types.



/// Address space of a pointer, mirroring PTX state spaces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AddrSpace {
    /// Off-chip device memory (`.global` in PTX). Expensive; the paper's
    /// headline wins come from removing per-iteration accesses here.
    Global,
    /// Per-thread local storage (`.local`, the `__local_depot` of §3.4).
    /// Cheap: it maps to registers or L1-resident spill space.
    Local,
}

/// Value types. `F32` matches the paper's single-precision PolyBench setup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Ty {
    /// 1-bit predicate (comparison results).
    I1,
    /// 32-bit signed integer (loop counters, indices).
    I32,
    /// 64-bit signed integer (byte offsets, extended indices).
    I64,
    /// 32-bit IEEE float (all PolyBench payload data).
    F32,
    /// Pointer into an address space. Pointees are always `F32` arrays in
    /// this suite; loads/stores carry the element type implicitly.
    Ptr(AddrSpace),
    /// Instruction produces no value (store, branches).
    Void,
}

impl Ty {
    pub fn is_ptr(self) -> bool {
        matches!(self, Ty::Ptr(_))
    }
    pub fn is_int(self) -> bool {
        matches!(self, Ty::I32 | Ty::I64)
    }
    pub fn is_float(self) -> bool {
        matches!(self, Ty::F32)
    }
}
