//! Basic blocks.

use super::inst::InstId;

/// Index into `Function::blocks`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

/// A basic block: an ordered list of instruction ids ending in a
/// terminator, plus explicit CFG edges. Phi operands are positionally
/// aligned with `preds`.
#[derive(Debug, Clone, Default)]
pub struct Block {
    pub name: String,
    pub insts: Vec<InstId>,
    pub preds: Vec<BlockId>,
    pub succs: Vec<BlockId>,
    /// Backend unroll hint for the loop headed by this block (1 = none).
    /// Mirrors `llvm.loop.unroll.count` metadata: set by the frontend
    /// (CUDA variants arrive with 8–16, OpenCL with 2–4, per §3.4) and by
    /// the `loop-unroll` pass; consumed by codegen and the cost model.
    pub unroll: u8,
    /// Set by `bb-vectorize` when this block contains provably-adjacent
    /// load/store pairs; codegen then emits `ld.v2`-style paired accesses
    /// for them (the backend does the fusion, the pass does the proof).
    pub vectorize_hint: bool,
}

impl Block {
    pub fn new(name: impl Into<String>) -> Block {
        Block {
            name: name.into(),
            unroll: 1,
            ..Default::default()
        }
    }
    /// Index of `p` in the predecessor list (phi operand position).
    pub fn pred_index(&self, p: BlockId) -> Option<usize> {
        self.preds.iter().position(|&x| x == p)
    }
}
