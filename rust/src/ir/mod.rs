//! A compact SSA intermediate representation modelled on LLVM IR.
//!
//! This is the substrate the whole reproduction stands on: the paper's
//! phase-ordering phenomena are pass-over-IR interactions, so the IR keeps
//! the properties those interactions need — SSA values, an explicit CFG,
//! typed memory operations with address-space distinction (global vs.
//! per-thread local), phi nodes, and loop-carried accumulation through
//! memory (the pattern §3.4 of the paper identifies as the dominant
//! optimization opportunity).
//!
//! Design choices (and why):
//! - Instructions are `Copy` and live in a flat arena per function, so a
//!   DSE evaluation can clone a kernel in one `memcpy`-ish step. The DSE
//!   hot loop clones the baseline module for every candidate sequence.
//! - Operand lists are fixed-size (`[Value; MAX_ARGS]`); phi arity is
//!   bounded by predecessor count, which our structured kernels keep ≤ 4.
//! - Loop unrolling is represented as a per-header hint consumed by the
//!   cost model (like `llvm.loop.unroll` metadata feeding the backend),
//!   not as body duplication; the paper's unroll observations are made at
//!   the PTX level, which our codegen reproduces from the hint.
//! - Cross-pass module state is *typed* ([`PipelineState`]: the alias
//!   summary and its staleness, CFG facts, alloca form, outlining)
//!   rather than ad-hoc flags — the order-matters mechanism the DSE
//!   explores. Structural invariants are enforced by [`verifier`]
//!   (every pass sequence must leave verifier-clean IR; the CLI's
//!   `--verify-each` runs it after every changing pass).

pub mod block;
pub mod builder;
pub mod dom;
pub mod function;
pub mod inst;
pub mod loops;
pub mod module;
pub mod parser;
pub mod printer;
pub mod types;
pub mod value;
pub mod verifier;

pub use block::{Block, BlockId};
pub use builder::KernelBuilder;
pub use dom::DomTree;
pub use function::{Function, Param};
pub use inst::{CmpPred, Inst, InstId, Op, MAX_ARGS};
pub use loops::{Loop, LoopForest};
pub use module::{
    AaPrecision, AliasSummary, AllocaForm, CfgFacts, Module, Outlining, PipelineState,
};
pub use types::{AddrSpace, Ty};
pub use value::Value;
