//! Thin wrapper over the `xla` crate (PJRT C API, CPU plugin).
//!
//! Interchange format is HLO *text*: jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Default artifacts directory: `$PHASEORD_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PHASEORD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A PJRT CPU client + compiled golden executables, loaded on demand.
pub struct GoldenRunner {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl GoldenRunner {
    pub fn new(dir: impl AsRef<Path>) -> Result<GoldenRunner> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(GoldenRunner {
            client,
            dir: dir.as_ref().to_path_buf(),
        })
    }

    pub fn from_env() -> Result<GoldenRunner> {
        Self::new(artifacts_dir())
    }

    pub fn artifact_path(&self, bench: &str) -> PathBuf {
        self.dir.join(format!("{bench}.hlo.txt"))
    }

    pub fn has_artifact(&self, bench: &str) -> bool {
        self.artifact_path(bench).exists()
    }

    /// Execute a benchmark's golden model (zero-arg) and return its
    /// output buffers (f32, flattened), in the model's declared order.
    pub fn run(&self, bench: &str) -> Result<Vec<Vec<f32>>> {
        let path = self.artifact_path(bench);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {bench}"))?;
        let result = exe
            .execute::<xla::Literal>(&[])
            .with_context(|| format!("executing {bench}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // models lower with return_tuple=True
        let parts = lit.to_tuple().context("decomposing result tuple")?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().context("reading f32 output")?);
        }
        Ok(out)
    }
}
