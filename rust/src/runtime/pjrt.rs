//! Golden-artifact loader.
//!
//! The JAX/Pallas golden models are lowered once, ahead of time, by
//! `python -m compile.aot` (`make artifacts`), which writes two files per
//! benchmark under `artifacts/`:
//!
//! * `<BENCH>.hlo.txt` — the lowered HLO text (kept for inspection and
//!   for external PJRT tooling);
//! * `<BENCH>.golden.txt` — the executed model's output buffers, the
//!   numbers the DSE validator actually consumes.
//!
//! Earlier revisions executed the HLO at DSE time through the `xla`
//! crate's PJRT bindings; the vendored crate set has neither `xla` nor
//! `anyhow`, so the runner now reads the outputs dumped at AOT time.
//! The three-layer seam is unchanged: Python authors and executes the
//! models once, and at DSE time only this rust path runs — with zero
//! external dependencies.

use std::fmt;
use std::path::{Path, PathBuf};

/// Runtime-layer failure (artifact missing/corrupt).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Default artifacts directory: `$PHASEORD_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PHASEORD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Loader for the AOT golden outputs, resolved on demand per benchmark.
pub struct GoldenRunner {
    dir: PathBuf,
}

impl GoldenRunner {
    pub fn new(dir: impl AsRef<Path>) -> Result<GoldenRunner> {
        Ok(GoldenRunner {
            dir: dir.as_ref().to_path_buf(),
        })
    }

    pub fn from_env() -> Result<GoldenRunner> {
        Self::new(artifacts_dir())
    }

    /// The lowered HLO text artifact (informational; not read at DSE time).
    pub fn hlo_path(&self, bench: &str) -> PathBuf {
        self.dir.join(format!("{bench}.hlo.txt"))
    }

    /// The golden-output dump consumed by the validator.
    pub fn artifact_path(&self, bench: &str) -> PathBuf {
        self.dir.join(format!("{bench}.golden.txt"))
    }

    pub fn has_artifact(&self, bench: &str) -> bool {
        self.artifact_path(bench).exists()
    }

    /// Load a benchmark's golden output buffers (f32, flattened), in the
    /// model's declared order.
    pub fn run(&self, bench: &str) -> Result<Vec<Vec<f32>>> {
        let path = self.artifact_path(bench);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| err(format!("reading {}: {e}", path.display())))?;
        parse_golden(&text).map_err(|e| err(format!("{}: {}", path.display(), e.0)))
    }
}

/// Artifact format: one output buffer per line, values space-separated
/// (shortest-round-trip decimals written by `python -m compile.aot`);
/// blank lines and `#` comments are skipped.
fn parse_golden(text: &str) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut buf = Vec::new();
        for tok in line.split_whitespace() {
            let v: f32 = tok
                .parse()
                .map_err(|e| err(format!("line {}: bad f32 {tok:?}: {e}", ln + 1)))?;
            buf.push(v);
        }
        out.push(buf);
    }
    if out.is_empty() {
        return Err(err("no output buffers in artifact"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_golden_roundtrip() {
        let got = parse_golden("# comment\n1.5 2.25 -0.5\n\n0.125\n").unwrap();
        assert_eq!(got, vec![vec![1.5, 2.25, -0.5], vec![0.125]]);
    }

    #[test]
    fn parse_golden_rejects_garbage() {
        assert!(parse_golden("").is_err());
        assert!(parse_golden("1.0 nope 2.0").is_err());
    }

    #[test]
    fn artifact_paths_are_per_bench() {
        let r = GoldenRunner::new("artifacts").unwrap();
        assert!(r
            .artifact_path("GEMM")
            .to_string_lossy()
            .ends_with("GEMM.golden.txt"));
        assert!(r.hlo_path("GEMM").to_string_lossy().ends_with("GEMM.hlo.txt"));
    }
}
