//! PJRT runtime: loads the AOT-lowered JAX/Pallas golden models
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them on the XLA CPU client. This is the three-layer seam: Python
//! authored the models, but at DSE time only this rust path runs.

pub mod golden;
pub mod pjrt;

pub use golden::golden_buffers;
pub use pjrt::{artifacts_dir, GoldenRunner};
