//! Golden-reference runtime: loads the AOT-dumped JAX/Pallas golden
//! outputs (`artifacts/*.golden.txt`, built once by `make artifacts` /
//! `python -m compile.aot`). This is the three-layer seam: Python
//! authored and executed the models once at AOT time; at DSE time only
//! this dependency-free rust path runs.

pub mod golden;
pub mod pjrt;

pub use golden::golden_buffers;
pub use pjrt::{artifacts_dir, GoldenRunner, RuntimeError};
