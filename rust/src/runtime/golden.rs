//! Golden buffer assembly: initialize a benchmark's buffers with the
//! deterministic fill and overwrite the *output* buffers with the JAX
//! model's AOT-dumped results. The DSE validator compares every
//! candidate compilation against these (paper §2.4).

use super::pjrt::{GoldenRunner, Result, RuntimeError};
use crate::bench_suite::{init_buffers, Benchmark, Variant};
use crate::sim::exec::Buffers;

/// Golden outputs for `bench` at validation size, from the AOT artifact.
pub fn golden_buffers(runner: &GoldenRunner, bench: &Benchmark) -> Result<Buffers> {
    let built = bench.build_small(Variant::OpenCl);
    let mut bufs = init_buffers(&built);
    let outs = runner.run(bench.name)?;
    if outs.len() != built.outputs.len() {
        return Err(RuntimeError(format!(
            "{}: artifact has {} outputs, benchmark declares {}",
            bench.name,
            outs.len(),
            built.outputs.len()
        )));
    }
    for (slot, data) in built.outputs.iter().zip(outs) {
        if bufs.bufs[*slot].len() != data.len() {
            return Err(RuntimeError(format!(
                "{}: output {} size mismatch ({} vs {})",
                bench.name,
                slot,
                bufs.bufs[*slot].len(),
                data.len()
            )));
        }
        bufs.bufs[*slot] = data;
    }
    Ok(bufs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::{all_benchmarks, execute, init_buffers, outputs_match, Variant};

    /// THE cross-language validation: for every benchmark, the rust
    /// interpreter executing the unoptimized OpenCL IR must agree with
    /// the JAX model's AOT golden dump, within the paper's 1%.
    /// (Skipped when `make artifacts` hasn't run.)
    #[test]
    fn interpreter_matches_aot_golden_for_all_benchmarks() {
        let runner = match GoldenRunner::from_env() {
            Ok(r) => r,
            Err(e) => panic!("golden runner unavailable: {e}"),
        };
        if !runner.has_artifact("GEMM") {
            eprintln!("artifacts/ missing — run `make artifacts`; skipping");
            return;
        }
        for b in all_benchmarks() {
            let golden = golden_buffers(&runner, &b)
                .unwrap_or_else(|e| panic!("{}: {e}", b.name));
            let built = b.build_small(Variant::OpenCl);
            let mut got = init_buffers(&built);
            execute(&built, &mut got, 400_000_000).unwrap();
            assert!(
                outputs_match(&built, &got, &golden, 0.01),
                "{}: interpreter vs JAX golden mismatch",
                b.name
            );
        }
    }
}
