//! Linear-algebra benchmarks: 2MM, 3MM, ATAX, BICG, GEMM, GESUMMV,
//! GRAMSCHM, MVT, SYR2K, SYRK — built with the exact loop/memory shape
//! of the PolyBench/GPU OpenCL kernels (accumulation through global
//! memory inside the reduction loops).

use super::builders::*;
use super::{cudaify, set_innermost_unroll, Benchmark, BuiltBench, Dims, KernelInfo, Variant};
use crate::ir::{CmpPred, KernelBuilder, Module, Ty, Value};

fn finalize(mut module: Module, v: Variant, kernels: Vec<KernelInfo>, buf_sizes: Vec<usize>, outputs: Vec<usize>) -> BuiltBench {
    match v {
        Variant::OpenCl => {
            for f in &mut module.kernels {
                set_innermost_unroll(f, 2);
            }
        }
        Variant::Cuda => cudaify(&mut module, 8),
    }
    BuiltBench::simple(module, kernels, buf_sizes, outputs)
}

/// One matmul-style kernel: `out[i*n+j] = init; for k: out += a_row ·
/// b_col` with `i = gid.1`, `j = gid.0`.
fn mm_kernel(name: &str, n: usize, params: &[&str], a: usize, b_: usize, out: usize, zero_init: bool) -> crate::ir::Function {
    let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
    let mut b = KernelBuilder::new(name, &plist);
    guard2(&mut b, n, n, |b, i, j| {
        let cidx = idx2(b, i, j, n);
        if zero_init {
            b.store(b.param(out), cidx, b.fc(0.0));
        } else {
            // c *= beta
            let c0 = b.load(b.param(out), cidx);
            let c1 = b.fmul(c0, b.fc(BETA));
            b.store(b.param(out), cidx, c1);
        }
        let nn = b.i(n as i64);
        b.for_loop("k", b.i(0), nn, 1, |b, k| {
            let aidx = idx2(b, i, k, n);
            let bidx = idx2(b, k, j, n);
            let av = b.load(b.param(a), aidx);
            let bv = b.load(b.param(b_), bidx);
            let prod = b.fmul(av, bv);
            let scaled = b.fmul(prod, b.fc(ALPHA));
            rmw_add(b, b.param(out), cidx, scaled);
        });
    });
    b.finish()
}

pub fn gemm() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let mut m = Module::new("GEMM");
        m.kernels.push(mm_kernel("gemm_kernel", n, &["a", "b", "c"], 0, 1, 2, false));
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, n), repeat: 1 }],
            vec![n * n, n * n, n * n],
            vec![2],
        )
    }
    Benchmark {
        name: "GEMM",
        family: "linear-algebra",
        dims_full: Dims { n: 1024, m: 1024, tmax: 1 },
        dims_small: Dims { n: 12, m: 12, tmax: 1 },
        build,
    }
}

pub fn mm2() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let mut m = Module::new("2MM");
        // tmp = A×B ; D = tmp×C   (buffers: a, b, c, tmp, dd)
        m.kernels.push(mm_kernel("mm2_kernel1", n, &["a", "b", "c", "tmp", "dd"], 0, 1, 3, true));
        m.kernels.push(mm_kernel("mm2_kernel2", n, &["a", "b", "c", "tmp", "dd"], 3, 2, 4, true));
        finalize(
            m,
            v,
            vec![
                KernelInfo { grid: (n, n), repeat: 1 },
                KernelInfo { grid: (n, n), repeat: 1 },
            ],
            vec![n * n; 5],
            vec![4],
        )
    }
    Benchmark {
        name: "2MM",
        family: "linear-algebra",
        dims_full: Dims { n: 1024, m: 1024, tmax: 1 },
        dims_small: Dims { n: 12, m: 12, tmax: 1 },
        build,
    }
}

pub fn mm3() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let mut m = Module::new("3MM");
        // E = A×B ; F = C×D ; G = E×F (buffers: a,b,c,dd,e,ff,g)
        let params = &["a", "b", "c", "dd", "e", "ff", "g"];
        m.kernels.push(mm_kernel("mm3_kernel1", n, params, 0, 1, 4, true));
        m.kernels.push(mm_kernel("mm3_kernel2", n, params, 2, 3, 5, true));
        m.kernels.push(mm_kernel("mm3_kernel3", n, params, 4, 5, 6, true));
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, n), repeat: 1 }; 3],
            vec![n * n; 7],
            vec![6],
        )
    }
    Benchmark {
        name: "3MM",
        family: "linear-algebra",
        dims_full: Dims { n: 1024, m: 1024, tmax: 1 },
        dims_small: Dims { n: 10, m: 10, tmax: 1 },
        build,
    }
}

pub fn atax() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "x", "y", "tmp"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("ATAX");
        // kernel1: per-row reduction tmp[i] = Σ_j A[i][j]·x[j]
        {
            let mut b = KernelBuilder::new("atax_kernel1", &plist);
            guard1(&mut b, n, |b, i| {
                b.store(b.param(3), i, b.fc(0.0));
                let nn = b.i(n as i64);
                b.for_loop("j", b.i(0), nn, 1, |b, j| {
                    let aidx = idx2(b, i, j, n);
                    let av = b.load(b.param(0), aidx);
                    let xv = b.load(b.param(1), j);
                    let prod = b.fmul(av, xv);
                    rmw_add(b, b.param(3), i, prod);
                });
            });
            m.kernels.push(b.finish());
        }
        // kernel2: per-column reduction y[j] = Σ_i A[i][j]·tmp[i]
        {
            let mut b = KernelBuilder::new("atax_kernel2", &plist);
            guard1(&mut b, n, |b, j| {
                b.store(b.param(2), j, b.fc(0.0));
                let nn = b.i(n as i64);
                b.for_loop("i", b.i(0), nn, 1, |b, i| {
                    let aidx = idx2(b, i, j, n);
                    let av = b.load(b.param(0), aidx);
                    let tv = b.load(b.param(3), i);
                    let prod = b.fmul(av, tv);
                    rmw_add(b, b.param(2), j, prod);
                });
            });
            m.kernels.push(b.finish());
        }
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, 1), repeat: 1 }; 2],
            vec![n * n, n, n, n],
            vec![2],
        )
    }
    Benchmark {
        name: "ATAX",
        family: "linear-algebra",
        dims_full: Dims { n: 4096, m: 4096, tmax: 1 },
        dims_small: Dims { n: 24, m: 24, tmax: 1 },
        build,
    }
}

pub fn bicg() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "p", "q", "r", "s"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("BICG");
        // kernel1: s[j] = Σ_i r[i]·A[i][j]
        {
            let mut b = KernelBuilder::new("bicg_kernel1", &plist);
            guard1(&mut b, n, |b, j| {
                b.store(b.param(4), j, b.fc(0.0));
                let nn = b.i(n as i64);
                b.for_loop("i", b.i(0), nn, 1, |b, i| {
                    let aidx = idx2(b, i, j, n);
                    let rv = b.load(b.param(3), i);
                    let av = b.load(b.param(0), aidx);
                    let prod = b.fmul(rv, av);
                    rmw_add(b, b.param(4), j, prod);
                });
            });
            m.kernels.push(b.finish());
        }
        // kernel2: q[i] = Σ_j A[i][j]·p[j]
        {
            let mut b = KernelBuilder::new("bicg_kernel2", &plist);
            guard1(&mut b, n, |b, i| {
                b.store(b.param(2), i, b.fc(0.0));
                let nn = b.i(n as i64);
                b.for_loop("j", b.i(0), nn, 1, |b, j| {
                    let aidx = idx2(b, i, j, n);
                    let av = b.load(b.param(0), aidx);
                    let pv = b.load(b.param(1), j);
                    let prod = b.fmul(av, pv);
                    rmw_add(b, b.param(2), i, prod);
                });
            });
            m.kernels.push(b.finish());
        }
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, 1), repeat: 1 }; 2],
            vec![n * n, n, n, n, n],
            vec![2, 4],
        )
    }
    Benchmark {
        name: "BICG",
        family: "linear-algebra",
        dims_full: Dims { n: 4096, m: 4096, tmax: 1 },
        dims_small: Dims { n: 24, m: 24, tmax: 1 },
        build,
    }
}

pub fn mvt() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "x1", "x2", "y1", "y2"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("MVT");
        // x1[i] += Σ_j A[i][j]·y1[j]   (accumulates onto existing x1)
        {
            let mut b = KernelBuilder::new("mvt_kernel1", &plist);
            guard1(&mut b, n, |b, i| {
                let nn = b.i(n as i64);
                b.for_loop("j", b.i(0), nn, 1, |b, j| {
                    let aidx = idx2(b, i, j, n);
                    let av = b.load(b.param(0), aidx);
                    let yv = b.load(b.param(3), j);
                    let prod = b.fmul(av, yv);
                    rmw_add(b, b.param(1), i, prod);
                });
            });
            m.kernels.push(b.finish());
        }
        // x2[i] += Σ_j A[j][i]·y2[j]
        {
            let mut b = KernelBuilder::new("mvt_kernel2", &plist);
            guard1(&mut b, n, |b, i| {
                let nn = b.i(n as i64);
                b.for_loop("j", b.i(0), nn, 1, |b, j| {
                    let aidx = idx2(b, j, i, n);
                    let av = b.load(b.param(0), aidx);
                    let yv = b.load(b.param(4), j);
                    let prod = b.fmul(av, yv);
                    rmw_add(b, b.param(2), i, prod);
                });
            });
            m.kernels.push(b.finish());
        }
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, 1), repeat: 1 }; 2],
            vec![n * n, n, n, n, n],
            vec![1, 2],
        )
    }
    Benchmark {
        name: "MVT",
        family: "linear-algebra",
        dims_full: Dims { n: 4096, m: 4096, tmax: 1 },
        dims_small: Dims { n: 24, m: 24, tmax: 1 },
        build,
    }
}

pub fn gesummv() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "b", "x", "y", "tmp"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("GESUMMV");
        // y[i] = α·(A·x)[i] + β·(B·x)[i], two memory accumulators in one
        // loop (the paper notes GESUMMV's phase-ordered version keeps a
        // smaller unroll but still extracts both stores)
        let mut b = KernelBuilder::new("gesummv_kernel", &plist);
        guard1(&mut b, n, |b, i| {
            b.store(b.param(4), i, b.fc(0.0));
            b.store(b.param(3), i, b.fc(0.0));
            let nn = b.i(n as i64);
            b.for_loop("j", b.i(0), nn, 1, |b, j| {
                let aidx = idx2(b, i, j, n);
                let av = b.load(b.param(0), aidx);
                let xv = b.load(b.param(2), j);
                let p1 = b.fmul(av, xv);
                rmw_add(b, b.param(4), i, p1);
                let bidx = idx2(b, i, j, n);
                let bv = b.load(b.param(1), bidx);
                let xv2 = b.load(b.param(2), j);
                let p2 = b.fmul(bv, xv2);
                rmw_add(b, b.param(3), i, p2);
            });
            let tv = b.load(b.param(4), i);
            let yv = b.load(b.param(3), i);
            let at = b.fmul(tv, b.fc(ALPHA));
            let by = b.fmul(yv, b.fc(BETA));
            let s = b.fadd(at, by);
            b.store(b.param(3), i, s);
        });
        m.kernels.push(b.finish());
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, 1), repeat: 1 }],
            vec![n * n, n * n, n, n, n],
            vec![3],
        )
    }
    Benchmark {
        name: "GESUMMV",
        family: "linear-algebra",
        dims_full: Dims { n: 4096, m: 4096, tmax: 1 },
        dims_small: Dims { n: 20, m: 20, tmax: 1 },
        build,
    }
}

pub fn syrk() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "c"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("SYRK");
        // c[i][j] = β·c + α·Σ_k a[i][k]·a[j][k]
        let mut b = KernelBuilder::new("syrk_kernel", &plist);
        guard2(&mut b, n, n, |b, i, j| {
            let cidx = idx2(b, i, j, n);
            let c0 = b.load(b.param(1), cidx);
            let c1 = b.fmul(c0, b.fc(BETA));
            b.store(b.param(1), cidx, c1);
            let nn = b.i(n as i64);
            b.for_loop("k", b.i(0), nn, 1, |b, k| {
                let ai = idx2(b, i, k, n);
                let aj = idx2(b, j, k, n);
                let av = b.load(b.param(0), ai);
                let bv = b.load(b.param(0), aj);
                let prod = b.fmul(av, bv);
                let scaled = b.fmul(prod, b.fc(ALPHA));
                rmw_add(b, b.param(1), cidx, scaled);
            });
        });
        m.kernels.push(b.finish());
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, n), repeat: 1 }],
            vec![n * n, n * n],
            vec![1],
        )
    }
    Benchmark {
        name: "SYRK",
        family: "linear-algebra",
        dims_full: Dims { n: 1024, m: 1024, tmax: 1 },
        dims_small: Dims { n: 12, m: 12, tmax: 1 },
        build,
    }
}

pub fn syr2k() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "b", "c"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("SYR2K");
        // c[i][j] = β·c + α·Σ_k (a[i][k]·b[j][k] + b[i][k]·a[j][k])
        let mut b = KernelBuilder::new("syr2k_kernel", &plist);
        guard2(&mut b, n, n, |b, i, j| {
            let cidx = idx2(b, i, j, n);
            let c0 = b.load(b.param(2), cidx);
            let c1 = b.fmul(c0, b.fc(BETA));
            b.store(b.param(2), cidx, c1);
            let nn = b.i(n as i64);
            b.for_loop("k", b.i(0), nn, 1, |b, k| {
                let aik = idx2(b, i, k, n);
                let bjk = idx2(b, j, k, n);
                let bik = idx2(b, i, k, n);
                let ajk = idx2(b, j, k, n);
                let av = b.load(b.param(0), aik);
                let bv = b.load(b.param(1), bjk);
                let p1 = b.fmul(av, bv);
                let bv2 = b.load(b.param(1), bik);
                let av2 = b.load(b.param(0), ajk);
                let p2 = b.fmul(bv2, av2);
                let s = b.fadd(p1, p2);
                let scaled = b.fmul(s, b.fc(ALPHA));
                rmw_add(b, b.param(2), cidx, scaled);
            });
        });
        m.kernels.push(b.finish());
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, n), repeat: 1 }],
            vec![n * n, n * n, n * n],
            vec![2],
        )
    }
    Benchmark {
        name: "SYR2K",
        family: "linear-algebra",
        dims_full: Dims { n: 1024, m: 1024, tmax: 1 },
        dims_small: Dims { n: 12, m: 12, tmax: 1 },
        build,
    }
}

pub fn gramschm() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        // buffers: a (n*n), r (n*n), q (n*n), host scalars
        let params = &["a", "r", "q", "host"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("GRAMSCHM");
        let read_k = |b: &mut KernelBuilder| -> Value {
            let kf = b.load(b.param(3), b.i(0));
            b.fptosi(kf)
        };
        // kernel1 (1 thread): r[k][k] = sqrt(Σ_i a[i][k]²)
        {
            let mut b = KernelBuilder::new("gramschmidt_kernel1", &plist);
            let k = read_k(&mut b);
            let rkk = idx2(&mut b, k, k, n);
            b.store(b.param(1), rkk, b.fc(0.0));
            let nn = b.i(n as i64);
            b.for_loop("i", b.i(0), nn, 1, |b, i| {
                let aik = idx2(b, i, k, n);
                let av = b.load(b.param(0), aik);
                let sq = b.fmul(av, av);
                rmw_add(b, b.param(1), rkk, sq);
            });
            let acc = b.load(b.param(1), rkk);
            let root = b.fsqrt(acc);
            b.store(b.param(1), rkk, root);
            m.kernels.push(b.finish());
        }
        // kernel2: q[i][k] = a[i][k] / r[k][k]
        {
            let mut b = KernelBuilder::new("gramschmidt_kernel2", &plist);
            let k = read_k(&mut b);
            guard1(&mut b, n, |b, i| {
                let aik = idx2(b, i, k, n);
                let rkk = idx2(b, k, k, n);
                let av = b.load(b.param(0), aik);
                let rv = b.load(b.param(1), rkk);
                let qv = b.fdiv(av, rv);
                b.store(b.param(2), aik, qv);
            });
            m.kernels.push(b.finish());
        }
        // kernel3: for j > k: r[k][j] = Σ_i q[i][k]·a[i][j]; then
        //          a[i][j] -= q[i][k]·r[k][j]
        {
            let mut b = KernelBuilder::new("gramschmidt_kernel3", &plist);
            let k = read_k(&mut b);
            let j = b.gid(0);
            let upper = b.icmp(CmpPred::Lt, j, b.i(n as i64));
            let lower = b.icmp(CmpPred::Gt, j, k);
            let c = b.and(upper, lower);
            b.if_then(c, |b| {
                let rkj = idx2(b, k, j, n);
                b.store(b.param(1), rkj, b.fc(0.0));
                let nn = b.i(n as i64);
                b.for_loop("i", b.i(0), nn, 1, |b, i| {
                    let qik = idx2(b, i, k, n);
                    let aij = idx2(b, i, j, n);
                    let qv = b.load(b.param(2), qik);
                    let av = b.load(b.param(0), aij);
                    let prod = b.fmul(qv, av);
                    rmw_add(b, b.param(1), rkj, prod);
                });
                let nn2 = b.i(n as i64);
                b.for_loop("i2", b.i(0), nn2, 1, |b, i| {
                    let qik = idx2(b, i, k, n);
                    let aij = idx2(b, i, j, n);
                    let qv = b.load(b.param(2), qik);
                    let rv = b.load(b.param(1), rkj);
                    let prod = b.fmul(qv, rv);
                    let av = b.load(b.param(0), aij);
                    let diff = b.fsub(av, prod);
                    b.store(b.param(0), aij, diff);
                });
            });
            m.kernels.push(b.finish());
        }
        let mut built = finalize(
            m,
            v,
            vec![
                KernelInfo { grid: (1, 1), repeat: 1 },
                KernelInfo { grid: (n, 1), repeat: 1 },
                KernelInfo { grid: (n, 1), repeat: 1 },
            ],
            vec![n * n, n * n, n * n, 4],
            vec![0, 2],
        );
        built.seq_repeat = n;
        built.host_step = Some(|bufs, t| {
            let last = bufs.bufs.len() - 1;
            bufs.bufs[last][0] = t as f32;
        });
        built
    }
    Benchmark {
        name: "GRAMSCHM",
        family: "linear-algebra",
        dims_full: Dims { n: 512, m: 512, tmax: 1 },
        dims_small: Dims { n: 6, m: 6, tmax: 1 },
        build,
    }
}
