//! Stencil benchmark: FDTD-2D — three field-update kernels driven by a
//! host time loop. "Very straightforward, with little potential for
//! optimization" (§3.4): no loop-carried memory accumulation, so phase
//! ordering finds nothing, matching the paper.

use super::builders::*;
use super::{cudaify, set_innermost_unroll, Benchmark, BuiltBench, Dims, KernelInfo, Variant};
use crate::ir::{CmpPred, KernelBuilder, Module, Ty};

fn finalize(mut module: Module, v: Variant, kernels: Vec<KernelInfo>, buf_sizes: Vec<usize>, outputs: Vec<usize>) -> BuiltBench {
    match v {
        Variant::OpenCl => {
            for f in &mut module.kernels {
                set_innermost_unroll(f, 2);
            }
        }
        Variant::Cuda => cudaify(&mut module, 8),
    }
    BuiltBench::simple(module, kernels, buf_sizes, outputs)
}

pub fn fdtd_2d() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let tmax = d.tmax;
        // buffers: fict(tmax), ex(n*n), ey(n*n), hz(n*n), host(4)
        let params = &["fict", "ex", "ey", "hz", "host"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("FDTD-2D");
        // kernel1: ey update (+ fict source row)
        {
            let mut b = KernelBuilder::new("fdtd_kernel1", &plist);
            let tf = b.load(b.param(4), b.i(0));
            let t = b.fptosi(tf);
            guard2(&mut b, n, n, |b, i, j| {
                let zero = b.icmp(CmpPred::Eq, i, b.i(0));
                let eyidx = idx2(b, i, j, n);
                // real if/else, as in the original source: the i-1 row
                // access must only execute on the interior branch
                let sel = b.if_then_else_val(
                    zero,
                    |b| b.load(b.param(0), t),
                    |b| {
                        let hz0 = b.load(b.param(3), eyidx);
                        let im1 = b.sub(i, b.i(1));
                        let hz1idx = idx2(b, im1, j, n);
                        let hz1 = b.load(b.param(3), hz1idx);
                        let diff = b.fsub(hz0, hz1);
                        let half = b.fmul(diff, b.fc(0.5));
                        let eyv = b.load(b.param(2), eyidx);
                        b.fsub(eyv, half)
                    },
                );
                b.store(b.param(2), eyidx, sel);
            });
            m.kernels.push(b.finish());
        }
        // kernel2: ex update
        {
            let mut b = KernelBuilder::new("fdtd_kernel2", &plist);
            guard2(&mut b, n, n, |b, i, j| {
                let pos = b.icmp(CmpPred::Gt, j, b.i(0));
                b.if_then(pos, |b| {
                    let exidx = idx2(b, i, j, n);
                    let hz0 = b.load(b.param(3), exidx);
                    let jm1 = b.sub(j, b.i(1));
                    let hz1idx = idx2(b, i, jm1, n);
                    let hz1 = b.load(b.param(3), hz1idx);
                    let diff = b.fsub(hz0, hz1);
                    let half = b.fmul(diff, b.fc(0.5));
                    let exv = b.load(b.param(1), exidx);
                    let upd = b.fsub(exv, half);
                    b.store(b.param(1), exidx, upd);
                });
            });
            m.kernels.push(b.finish());
        }
        // kernel3: hz update
        {
            let mut b = KernelBuilder::new("fdtd_kernel3", &plist);
            guard2(&mut b, n - 1, n - 1, |b, i, j| {
                let hzidx = idx2(b, i, j, n);
                let jp1 = b.add(j, b.i(1));
                let exr_idx = idx2(b, i, jp1, n);
                let ex0 = b.load(b.param(1), exr_idx);
                let ex1 = b.load(b.param(1), hzidx);
                let dex = b.fsub(ex0, ex1);
                let ip1 = b.add(i, b.i(1));
                let eyd_idx = idx2(b, ip1, j, n);
                let ey0 = b.load(b.param(2), eyd_idx);
                let ey1 = b.load(b.param(2), hzidx);
                let dey = b.fsub(ey0, ey1);
                let s = b.fadd(dex, dey);
                let scaled = b.fmul(s, b.fc(0.7));
                let hzv = b.load(b.param(3), hzidx);
                let upd = b.fsub(hzv, scaled);
                b.store(b.param(3), hzidx, upd);
            });
            m.kernels.push(b.finish());
        }
        let mut built = finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, n), repeat: 1 }; 3],
            vec![tmax.max(1), n * n, n * n, n * n, 4],
            vec![1, 2, 3],
        );
        built.seq_repeat = tmax;
        built.host_step = Some(|bufs, t| {
            let last = bufs.bufs.len() - 1;
            bufs.bufs[last][0] = t as f32;
        });
        built
    }
    Benchmark {
        name: "FDTD-2D",
        family: "stencil",
        dims_full: Dims { n: 2048, m: 2048, tmax: 500 },
        dims_small: Dims { n: 10, m: 10, tmax: 3 },
        build,
    }
}
