//! Convolution benchmarks: 2DCONV and 3DCONV.
//!
//! Load-dominated kernels with no loop-carried memory accumulation —
//! the benchmarks for which the paper's DSE finds *no* winning phase
//! order (Fig. 2 / Table 1 footnote). 2DCONV is straight-line per
//! thread; 3DCONV loops over the slowest dimension but stores to an
//! i-dependent address (nothing to promote).

use super::builders::*;
use super::{cudaify, set_innermost_unroll, Benchmark, BuiltBench, Dims, KernelInfo, Variant};
use crate::ir::{CmpPred, KernelBuilder, Module, Ty, Value};

// PolyBench 2DCONV stencil weights
const C11: f32 = 0.2;
const C12: f32 = -0.3;
const C13: f32 = 0.4;
const C21: f32 = 0.5;
const C22: f32 = 0.6;
const C23: f32 = 0.7;
const C31: f32 = -0.8;
const C32: f32 = -0.9;
const C33: f32 = 0.1;

fn finalize(mut module: Module, v: Variant, kernels: Vec<KernelInfo>, buf_sizes: Vec<usize>, outputs: Vec<usize>) -> BuiltBench {
    match v {
        Variant::OpenCl => {
            for f in &mut module.kernels {
                set_innermost_unroll(f, 2);
            }
        }
        Variant::Cuda => cudaify(&mut module, 8),
    }
    BuiltBench::simple(module, kernels, buf_sizes, outputs)
}

pub fn conv_2d() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "b"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("2DCONV");
        let mut b = KernelBuilder::new("convolution2d_kernel", &plist);
        // interior guard: 0 < i < n-1 && 0 < j < n-1
        let i = b.gid(1);
        let j = b.gid(0);
        let c1 = b.icmp(CmpPred::Gt, i, b.i(0));
        let c2 = b.icmp(CmpPred::Lt, i, b.i(n as i64 - 1));
        let c3 = b.icmp(CmpPred::Gt, j, b.i(0));
        let c4 = b.icmp(CmpPred::Lt, j, b.i(n as i64 - 1));
        let c12 = b.and(c1, c2);
        let c34 = b.and(c3, c4);
        let c = b.and(c12, c34);
        b.if_then(c, |b| {
            let mut acc: Option<Value> = None;
            for (di, dj, w) in [
                (-1, -1, C11),
                (-1, 0, C12),
                (-1, 1, C13),
                (0, -1, C21),
                (0, 0, C22),
                (0, 1, C23),
                (1, -1, C31),
                (1, 0, C32),
                (1, 1, C33),
            ] {
                let ii = b.add(i, b.i(di));
                let jj = b.add(j, b.i(dj));
                let aidx = idx2(b, ii, jj, n);
                let av = b.load(b.param(0), aidx);
                let term = b.fmul(av, b.fc(w));
                acc = Some(match acc {
                    None => term,
                    Some(prev) => b.fadd(prev, term),
                });
            }
            let bidx = idx2(b, i, j, n);
            b.store(b.param(1), bidx, acc.unwrap());
        });
        m.kernels.push(b.finish());
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, n), repeat: 1 }],
            vec![n * n, n * n],
            vec![1],
        )
    }
    Benchmark {
        name: "2DCONV",
        family: "convolution",
        dims_full: Dims { n: 4096, m: 4096, tmax: 1 },
        dims_small: Dims { n: 16, m: 16, tmax: 1 },
        build,
    }
}

pub fn conv_3d() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["a", "b"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("3DCONV");
        let mut b = KernelBuilder::new("convolution3d_kernel", &plist);
        // thread over (k = gid.0, j = gid.1); loop i over the slow dim
        let k = b.gid(0);
        let j = b.gid(1);
        let c1 = b.icmp(CmpPred::Gt, j, b.i(0));
        let c2 = b.icmp(CmpPred::Lt, j, b.i(n as i64 - 1));
        let c3 = b.icmp(CmpPred::Gt, k, b.i(0));
        let c4 = b.icmp(CmpPred::Lt, k, b.i(n as i64 - 1));
        let c12 = b.and(c1, c2);
        let c34 = b.and(c3, c4);
        let c = b.and(c12, c34);
        b.if_then(c, |b| {
            let hi = b.i(n as i64 - 1);
            b.for_loop("i", b.i(1), hi, 1, |b, i| {
                let mut acc: Option<Value> = None;
                for (di, dj, dk, w) in [
                    (-1, -1, -1, 0.2f32),
                    (0, -1, -1, -0.3),
                    (1, -1, 0, 0.4),
                    (-1, 0, 0, 0.5),
                    (0, 0, 0, 0.6),
                    (1, 0, 1, 0.7),
                    (-1, 1, 1, -0.8),
                    (0, 1, 1, -0.9),
                    (1, 1, -1, 0.1),
                ] {
                    let ii = b.add(i, b.i(di));
                    let jj = b.add(j, b.i(dj));
                    let kk = b.add(k, b.i(dk));
                    let aidx = idx3(b, ii, jj, kk, n);
                    let av = b.load(b.param(0), aidx);
                    let term = b.fmul(av, b.fc(w));
                    acc = Some(match acc {
                        None => term,
                        Some(prev) => b.fadd(prev, term),
                    });
                }
                let bidx = idx3(b, i, j, k, n);
                b.store(b.param(1), bidx, acc.unwrap());
            });
        });
        m.kernels.push(b.finish());
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, n), repeat: 1 }],
            vec![n * n * n, n * n * n],
            vec![1],
        )
    }
    Benchmark {
        name: "3DCONV",
        family: "convolution",
        dims_full: Dims { n: 256, m: 256, tmax: 1 },
        dims_small: Dims { n: 8, m: 8, tmax: 1 },
        build,
    }
}
