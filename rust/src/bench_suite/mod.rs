//! The PolyBench/GPU benchmark suite, rebuilt in our IR.
//!
//! All 15 benchmarks of the paper (§2.2), each with the loop/memory
//! structure of the real suite — in particular the memory-accumulation
//! idiom (`c[i*NJ+j] += …` inside the k-loop) whose promotion is the
//! paper's headline win, and the symmetric-index patterns of CORR
//! (`j2 = j1+1`) vs COVAR (`j2 = j1`, diagonal included) that interact
//! with the dse bug model.
//!
//! Each benchmark builds in two flavours (§3.1/§3.4):
//! * `Variant::OpenCl` — naive frontend addressing (Fig. 6's 5-inst
//!   pattern), innermost unroll hint 2 (driver default);
//! * `Variant::Cuda`  — what NVCC emits: strength-reduced addressing
//!   (`loop-reduce` applied at build) and unroll hint 8.
//!
//! Every kernel of a benchmark takes the *full* buffer list as params so
//! kernels can share one `Buffers` instance during simulation.

pub mod builders;
pub mod conv;
pub mod datamining;
pub mod irregular;
pub mod linalg;
pub mod stencil;

use std::collections::HashMap;
use std::sync::OnceLock;

use crate::ir::{Function, Module};
use crate::sim::exec::{run_kernel, Buffers, ExecError};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    OpenCl,
    Cuda,
}

/// Problem dimensions. Meaning is benchmark-specific (n×m matrices,
/// tmax stencil steps).
#[derive(Debug, Clone, Copy)]
pub struct Dims {
    pub n: usize,
    pub m: usize,
    pub tmax: usize,
}

/// Per-kernel launch info, aligned with `Module::kernels`.
#[derive(Debug, Clone)]
pub struct KernelInfo {
    pub grid: (usize, usize),
    /// host-side invocation count (e.g. FDTD's TMAX time steps)
    pub repeat: usize,
}

/// A built benchmark: module + launches + buffer plan.
#[derive(Clone)]
pub struct BuiltBench {
    pub module: Module,
    pub kernels: Vec<KernelInfo>,
    /// buffer sizes (elements), aligned with kernel params
    pub buf_sizes: Vec<usize>,
    /// which buffers constitute the checked output
    pub outputs: Vec<usize>,
    /// host-side repetitions of the whole kernel sequence (FDTD time
    /// steps, Gram-Schmidt column sweep); 1 for single-shot benchmarks
    pub seq_repeat: usize,
    /// host code run before each sequence iteration (e.g. writing the
    /// time-step / column index into the host-scalar buffer)
    pub host_step: Option<fn(&mut Buffers, usize)>,
}

impl BuiltBench {
    pub(crate) fn simple(
        module: Module,
        kernels: Vec<KernelInfo>,
        buf_sizes: Vec<usize>,
        outputs: Vec<usize>,
    ) -> BuiltBench {
        BuiltBench {
            module,
            kernels,
            buf_sizes,
            outputs,
            seq_repeat: 1,
            host_step: None,
        }
    }
}

#[derive(Clone, Copy)]
pub struct Benchmark {
    pub name: &'static str,
    pub family: &'static str,
    pub dims_full: Dims,
    pub dims_small: Dims,
    pub build: fn(&Dims, Variant) -> BuiltBench,
}

impl Benchmark {
    pub fn build_full(&self, v: Variant) -> BuiltBench {
        (self.build)(&self.dims_full, v)
    }
    pub fn build_small(&self, v: Variant) -> BuiltBench {
        (self.build)(&self.dims_small, v)
    }
}

/// The benchmark registry: the 15 PolyBench/GPU benchmarks in the
/// paper's order of mention, then the irregular-workload family. Built
/// once (the builders are cheap, but callers hit this on every lookup).
fn registry() -> &'static [Benchmark] {
    static LIST: OnceLock<Vec<Benchmark>> = OnceLock::new();
    LIST.get_or_init(|| {
        vec![
            conv::conv_2d(),
            conv::conv_3d(),
            linalg::mm2(),
            linalg::mm3(),
            linalg::atax(),
            linalg::bicg(),
            datamining::corr(),
            datamining::covar(),
            stencil::fdtd_2d(),
            linalg::gemm(),
            linalg::gesummv(),
            linalg::gramschm(),
            linalg::mvt(),
            linalg::syr2k(),
            linalg::syrk(),
            irregular::spmv(),
            irregular::treesum(),
            irregular::histo(),
            irregular::bfs(),
        ]
    })
}

pub fn all_benchmarks() -> Vec<Benchmark> {
    registry().to_vec()
}

/// Case-insensitive benchmark lookup through a lazily-built static
/// index (the `pass_by_name` pattern: the DSE resolves names in loops).
pub fn benchmark_by_name(name: &str) -> Option<Benchmark> {
    static INDEX: OnceLock<HashMap<String, usize>> = OnceLock::new();
    let index = INDEX.get_or_init(|| {
        registry()
            .iter()
            .enumerate()
            .map(|(i, b)| (b.name.to_ascii_lowercase(), i))
            .collect()
    });
    index
        .get(&name.to_ascii_lowercase())
        .map(|&i| registry()[i])
}

/// Error text for an unknown benchmark name: lists every valid name
/// grouped by family, in registry order. Shared by the CLI and the
/// serve daemon so both spell mistakes the same way.
pub fn unknown_benchmark_error(name: &str) -> String {
    let mut fams: Vec<(&str, Vec<&str>)> = Vec::new();
    for b in registry() {
        match fams.iter_mut().find(|(f, _)| *f == b.family) {
            Some((_, v)) => v.push(b.name),
            None => fams.push((b.family, vec![b.name])),
        }
    }
    let mut s = format!("unknown benchmark '{name}'; valid names by family:");
    for (f, names) in fams {
        s.push_str(&format!("\n  {f}: {}", names.join(", ")));
    }
    s
}

/// Deterministic non-zero initialization — identical formula in
/// `python/compile/model.py` so the PJRT golden outputs line up.
/// (The paper modified the original all-zeros init for the same reason:
/// to make wrong codegen observable.) The quadratic term keeps matrices
/// well-conditioned — a purely affine fill makes the Gram-Schmidt
/// residuals collapse into f32 cancellation noise.
pub fn fill_value(buf: usize, i: usize) -> f32 {
    (((i * i * 13 + i * 17 + buf * 31 + 7) % 101) as f32) / 101.0 + 0.5
}

pub fn init_buffers(b: &BuiltBench) -> Buffers {
    let mut bufs = Buffers::new(&b.buf_sizes);
    for (bi, buf) in bufs.bufs.iter_mut().enumerate() {
        for (i, x) in buf.iter_mut().enumerate() {
            *x = fill_value(bi, i);
        }
    }
    bufs
}

/// Execute all kernels of a built benchmark in order against `bufs`,
/// repeating the whole sequence `seq_repeat` times with the host step in
/// between. Returns the total interpreter steps (the DSE derives its
/// timeout from the baseline's count, like the paper's execution-time
/// timeout). Validation builds use small dims whose seq_repeat is small
/// enough to run in full.
pub fn execute(b: &BuiltBench, bufs: &mut Buffers, step_limit: u64) -> Result<u64, ExecError> {
    let mut total: u64 = 0;
    for t in 0..b.seq_repeat {
        if let Some(hs) = b.host_step {
            hs(bufs, t);
        }
        for (k, info) in b.module.kernels.iter().zip(&b.kernels) {
            for _ in 0..info.repeat {
                total += run_kernel(k, info.grid, bufs, step_limit.saturating_sub(total))?;
            }
        }
    }
    Ok(total)
}

/// Total modelled time (µs) for a built benchmark on a target.
pub fn model_time_us(b: &BuiltBench, target: &crate::sim::target::Target) -> f64 {
    model_time_us_ref(b, target, None)
}

/// Like [`model_time_us`], but with per-kernel fallback trip counts for
/// loops whose bounds the analysis can no longer see (supplied by the
/// DSE from the *baseline* build — see `sim::cost::estimate_time_unknown`).
/// Goes through the same [`crate::sim::cost::LoweredKernel`] path as the
/// staged evaluator (allocation feedback on), so reference and staged
/// pricing stay bit-identical by construction.
pub fn model_time_us_ref(
    b: &BuiltBench,
    target: &crate::sim::target::Target,
    unknown_trips: Option<&[f64]>,
) -> f64 {
    model_time_us_mode(b, target, unknown_trips, true)
}

/// [`model_time_us_ref`] with an explicit allocation-feedback mode: the
/// ablation entry point. `alloc_feedback = false` prices the vreg
/// programs at full occupancy (the pre-allocator model).
pub fn model_time_us_mode(
    b: &BuiltBench,
    target: &crate::sim::target::Target,
    unknown_trips: Option<&[f64]>,
    alloc_feedback: bool,
) -> f64 {
    let lowered: Vec<crate::sim::cost::LoweredKernel> = b
        .module
        .kernels
        .iter()
        .map(|k| {
            let mut lk = crate::sim::cost::LoweredKernel::lower(k, &b.module);
            lk.set_alloc_feedback(alloc_feedback);
            lk
        })
        .collect();
    model_time_us_lowered(&lowered, &b.kernels, b.seq_repeat, target, unknown_trips)
}

/// Price a pre-lowered build: `lowered` carries each kernel's cleaned
/// function, vPTX program and CFG analyses
/// ([`crate::sim::cost::LoweredKernel`], aligned with `infos`), so the
/// compile-once artifact of the staged evaluator can be measured on any
/// number of targets without re-lowering. Bit-identical to
/// [`model_time_us_ref`] over the module the artifact was lowered from.
pub fn model_time_us_lowered(
    lowered: &[crate::sim::cost::LoweredKernel],
    infos: &[KernelInfo],
    seq_repeat: usize,
    target: &crate::sim::target::Target,
    unknown_trips: Option<&[f64]>,
) -> f64 {
    let mut total = 0.0;
    for (ki, (lk, info)) in lowered.iter().zip(infos).enumerate() {
        let unknown = unknown_trips
            .and_then(|u| u.get(ki).copied())
            .unwrap_or(crate::sim::cost::UNKNOWN_TRIPS_DEFAULT);
        total += lk.estimate(info.grid, target, unknown).time_us * info.repeat as f64;
    }
    total * seq_repeat as f64
}

/// The full objective vector — `(time_us, energy_uj, code_size)` — for a
/// pre-lowered build. The time fold is kept textually identical to
/// [`model_time_us_lowered`] so `--objective time` stays bit-identical
/// to the scalar pipeline; energy scales with launches the same way
/// (each repeat spends the joules again), while code size is a *static*
/// property of the generated program and ignores repeat counts.
pub fn model_objectives_lowered(
    lowered: &[crate::sim::cost::LoweredKernel],
    infos: &[KernelInfo],
    seq_repeat: usize,
    target: &crate::sim::target::Target,
    unknown_trips: Option<&[f64]>,
) -> (f64, f64, f64) {
    let mut total = 0.0;
    let mut energy = 0.0;
    let mut size = 0.0;
    for (ki, (lk, info)) in lowered.iter().zip(infos).enumerate() {
        let unknown = unknown_trips
            .and_then(|u| u.get(ki).copied())
            .unwrap_or(crate::sim::cost::UNKNOWN_TRIPS_DEFAULT);
        let cb = lk.estimate(info.grid, target, unknown);
        total += cb.time_us * info.repeat as f64;
        energy += crate::sim::cost::estimate_energy_uj(&cb, info.grid, target) * info.repeat as f64;
        size += lk.code_size(target);
    }
    (total * seq_repeat as f64, energy * seq_repeat as f64, size)
}

/// [`model_objectives_lowered`] over a fresh lowering of `b`, with an
/// explicit allocation-feedback mode — the objective-vector sibling of
/// [`model_time_us_mode`]; `.0` is bit-identical to it.
pub fn model_objectives_mode(
    b: &BuiltBench,
    target: &crate::sim::target::Target,
    unknown_trips: Option<&[f64]>,
    alloc_feedback: bool,
) -> (f64, f64, f64) {
    let lowered: Vec<crate::sim::cost::LoweredKernel> = b
        .module
        .kernels
        .iter()
        .map(|k| {
            let mut lk = crate::sim::cost::LoweredKernel::lower(k, &b.module);
            lk.set_alloc_feedback(alloc_feedback);
            lk
        })
        .collect();
    model_objectives_lowered(&lowered, &b.kernels, b.seq_repeat, target, unknown_trips)
}

/// Baseline objective vector for a built benchmark (feedback on, no
/// trip-count overrides) — `.0` is bit-identical to [`model_time_us`].
pub fn model_objectives(b: &BuiltBench, target: &crate::sim::target::Target) -> (f64, f64, f64) {
    model_objectives_mode(b, target, None, true)
}

/// Per-kernel maximum baseline trip count (the DSE's pessimistic
/// fallback for analysis-defeating transformations).
pub fn baseline_max_trips(b: &BuiltBench, target: &crate::sim::target::Target) -> Vec<f64> {
    b.module
        .kernels
        .iter()
        .zip(&b.kernels)
        .map(|(k, info)| {
            let (cleaned, prog) = crate::codegen::lower(k, &b.module);
            let cb = crate::sim::cost::estimate_time(&cleaned, &prog, info.grid, target);
            cb.trips
                .iter()
                .map(|&(_, t)| t)
                .fold(crate::sim::cost::UNKNOWN_TRIPS_DEFAULT, f64::max)
        })
        .collect()
}

/// Relative output comparison with the paper's 1% tolerance (§2.4).
pub fn outputs_match(b: &BuiltBench, got: &Buffers, want: &Buffers, tol: f32) -> bool {
    for &oi in &b.outputs {
        let (g, w) = (&got.bufs[oi], &want.bufs[oi]);
        if g.len() != w.len() {
            return false;
        }
        for (x, y) in g.iter().zip(w.iter()) {
            if !x.is_finite() || !y.is_finite() {
                return false;
            }
            let denom = y.abs().max(1e-3);
            if (x - y).abs() / denom > tol {
                return false;
            }
        }
    }
    true
}

/// Shared by builders: finalize a CUDA-flavoured module — NVCC-style
/// strength-reduced addressing (loop accesses become pointer inductions,
/// straight-line accesses become base + constant-offset `[reg+imm]`
/// form) and higher unroll.
pub(crate) fn cudaify(m: &mut Module, unroll: u8) {
    let _ = crate::passes::run_single(&crate::passes::loop_reduce::LoopReduce, m);
    for f in &mut m.kernels {
        nvcc_addressing(f);
        set_innermost_unroll(f, unroll);
    }
    // NVCC's own toolchain: fresh analyses, none of our staleness
    m.state.alias.stale = false;
    m.state.cfg.dirty = false;
}

/// NVCC's constant-offset separation: rewrite `&buf[var_index + C]` as
/// `(&buf[var_index]) + 4C`, so the backend CSEs the shared variable base
/// across neighbouring accesses and folds the constant into the access
/// (`ld [%r+imm]` — the paper's Fig. 6a one-instruction load).
pub(crate) fn nvcc_addressing(f: &mut Function) {
    use crate::analysis::{AffineCtx, MemLoc, Root};
    use crate::ir::{AddrSpace, Inst, Op, Ty, Value};
    for bb in f.block_ids().collect::<Vec<_>>() {
        let ids = f.block(bb).insts.clone();
        for id in ids {
            let inst = *f.inst(id);
            if !inst.op.is_memory() {
                continue;
            }
            let loc = {
                let mut cx = AffineCtx::new(f);
                MemLoc::resolve(&mut cx, inst.args()[0])
            };
            let Root::Param(p) = loc.root else { continue };
            let Some(off) = loc.off else { continue };
            if off.konst == 0 || off.terms.is_empty() {
                continue;
            }
            // materialize the variable part right before the access; the
            // backend's machine CSE merges duplicates across accesses
            let pos = f
                .block(bb)
                .insts
                .iter()
                .position(|&x| x == id)
                .expect("inst in block");
            let mut cursor = pos;
            let emit = |f: &mut Function, cursor: &mut usize, inst: Inst| -> Value {
                let nid = f.add_inst(inst);
                f.block_mut(bb).insts.insert(*cursor, nid);
                *cursor += 1;
                Value::Inst(nid)
            };
            let mut acc: Option<Value> = None;
            for &(v, c) in &off.terms {
                let scaled = if c == 1 {
                    v
                } else {
                    emit(f, &mut cursor, Inst::new(Op::Mul, Ty::I64, &[v, Value::ImmI(c)]))
                };
                acc = Some(match acc {
                    None => scaled,
                    Some(prev) => {
                        emit(f, &mut cursor, Inst::new(Op::Add, Ty::I64, &[prev, scaled]))
                    }
                });
            }
            let base = emit(
                f,
                &mut cursor,
                Inst::new(
                    Op::PtrAdd,
                    Ty::Ptr(AddrSpace::Global),
                    &[Value::Arg(p), acc.expect("nonempty terms")],
                ),
            );
            let addr = emit(
                f,
                &mut cursor,
                Inst::new(
                    Op::PtrAdd,
                    Ty::Ptr(AddrSpace::Global),
                    &[base, Value::ImmI(off.konst)],
                ),
            );
            f.inst_mut(id).args_mut()[0] = addr;
        }
    }
    crate::passes::common::sweep_dead(f);
}

pub(crate) fn set_innermost_unroll(f: &mut Function, unroll: u8) {
    let (_dt, lf) = crate::passes::analyses::analyses_of(f);
    for (li, l) in lf.loops.iter().enumerate() {
        let is_innermost = !lf.loops.iter().enumerate().any(|(oi, o)| {
            oi != li && o.depth > l.depth && o.blocks.iter().all(|b| l.blocks.contains(b))
        });
        if is_innermost {
            f.block_mut(l.header).unroll = unroll;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_present() {
        let names: Vec<&str> = all_benchmarks().iter().map(|b| b.name).collect();
        assert_eq!(names.len(), 19);
        for n in [
            "2DCONV", "3DCONV", "2MM", "3MM", "ATAX", "BICG", "CORR", "COVAR", "FDTD-2D",
            "GEMM", "GESUMMV", "GRAMSCHM", "MVT", "SYR2K", "SYRK",
        ] {
            assert!(names.contains(&n), "missing {n}");
        }
        for n in ["SPMV", "TREESUM", "HISTO", "BFS"] {
            assert!(names.contains(&n), "missing {n}");
        }
        // the irregular family rides behind the paper's 15
        let irr: Vec<&str> = all_benchmarks()
            .iter()
            .filter(|b| b.family == "irregular")
            .map(|b| b.name)
            .collect();
        assert_eq!(irr, ["SPMV", "TREESUM", "HISTO", "BFS"]);
    }

    #[test]
    fn lookup_is_case_insensitive_and_errors_name_families() {
        assert_eq!(benchmark_by_name("gemm").unwrap().name, "GEMM");
        assert_eq!(benchmark_by_name("SpMv").unwrap().name, "SPMV");
        assert!(benchmark_by_name("nope").is_none());
        let e = unknown_benchmark_error("nope");
        assert!(e.contains("'nope'"));
        for fam in ["convolution", "linear-algebra", "irregular"] {
            assert!(e.contains(fam), "error misses family {fam}: {e}");
        }
        assert!(e.contains("GEMM") && e.contains("BFS"));
    }

    #[test]
    fn every_benchmark_builds_and_verifies() {
        use crate::ir::verifier::verify_module;
        for b in all_benchmarks() {
            for v in [Variant::OpenCl, Variant::Cuda] {
                let built = b.build_small(v);
                verify_module(&built.module)
                    .unwrap_or_else(|e| panic!("{} {:?}: {e}", b.name, v));
                assert_eq!(built.module.kernels.len(), built.kernels.len(), "{}", b.name);
                assert!(!built.outputs.is_empty(), "{}", b.name);
            }
        }
    }

    #[test]
    fn every_benchmark_executes_small() {
        for b in all_benchmarks() {
            let built = b.build_small(Variant::OpenCl);
            let mut bufs = init_buffers(&built);
            execute(&built, &mut bufs, 200_000_000)
                .unwrap_or_else(|e| panic!("{}: {e}", b.name));
        }
    }

    #[test]
    fn cuda_and_opencl_agree_functionally() {
        for b in all_benchmarks() {
            let bo = b.build_small(Variant::OpenCl);
            let bc = b.build_small(Variant::Cuda);
            let mut bufs_o = init_buffers(&bo);
            let mut bufs_c = init_buffers(&bc);
            execute(&bo, &mut bufs_o, 200_000_000).unwrap();
            execute(&bc, &mut bufs_c, 200_000_000).unwrap();
            assert!(
                outputs_match(&bo, &bufs_c, &bufs_o, 0.01),
                "{}: CUDA variant diverges from OpenCL",
                b.name
            );
        }
    }

    #[test]
    fn cuda_variant_models_faster_on_most() {
        // §3.1: CUDA baselines beat OpenCL baselines modestly (geomean
        // 1.07×) thanks to addressing + unroll
        let t = crate::sim::target::Target::gp104();
        let mut wins = 0;
        let mut total = 0;
        // §3.1's claim is over the PolyBench/GPU 15; the irregular
        // family's data-dependent loops price on fallback trips where
        // NVCC's addressing tricks barely register
        for b in all_benchmarks().into_iter().filter(|b| b.family != "irregular") {
            let to = model_time_us(&b.build_full(Variant::OpenCl), &t);
            let tc = model_time_us(&b.build_full(Variant::Cuda), &t);
            total += 1;
            if tc < to {
                wins += 1;
            }
        }
        assert!(wins * 2 > total, "CUDA should win on most: {wins}/{total}");
    }
}
