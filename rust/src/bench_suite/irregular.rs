//! Irregular-workload benchmarks: CSR SpMV, tree reduction, atomic
//! histogram (+ scan), and a frontier-based BFS step.
//!
//! Where the PolyBench/GPU kernels are dense and affine, this family
//! stresses exactly what those kernels cannot: indirect (gather)
//! addressing through index buffers, data-dependent loop trip counts
//! (CSR row degrees, frontier sizes), warp divergence from data-driven
//! guards, and atomic read-modify-writes (`atom.add`/`atom.max`) whose
//! contention the cost model prices per address class. Loop bounds read
//! from memory defeat `trip_count`, so the DSE's baseline-calibrated
//! fallback trips and step-limit/Timeout machinery bound the search —
//! the same way the paper's execution-time timeout bounds misoptimized
//! dense kernels.
//!
//! Graph/array *structure* (row pointers, column indices, frontiers) is
//! written by each benchmark's `host_step` from nothing but buffer
//! lengths, because `init_buffers` fills every buffer with the generic
//! `fill_value` pattern — meaningless as CSR offsets. Keeping structure
//! synthesis deterministic in plain host code preserves the suite-wide
//! bit-identity invariants (goldens, shards, stores) untouched.

use super::builders::*;
use super::{cudaify, set_innermost_unroll, Benchmark, BuiltBench, Dims, KernelInfo, Variant};
use crate::ir::{CmpPred, Function, KernelBuilder, Module};
use crate::sim::exec::Buffers;

fn finalize(
    mut module: Module,
    v: Variant,
    kernels: Vec<KernelInfo>,
    buf_sizes: Vec<usize>,
    outputs: Vec<usize>,
    seq_repeat: usize,
    host_step: fn(&mut Buffers, usize),
) -> BuiltBench {
    match v {
        Variant::OpenCl => {
            for f in &mut module.kernels {
                set_innermost_unroll(f, 2);
            }
        }
        Variant::Cuda => cudaify(&mut module, 8),
    }
    BuiltBench {
        module,
        kernels,
        buf_sizes,
        outputs,
        seq_repeat,
        host_step: Some(host_step),
    }
}

/// Write a deterministic CSR structure into `row_ptr` (buffer `rp`, n+1
/// entries) and `col_idx` (buffer `ci`, nnz entries, columns `< ncols`).
/// Row degrees vary irregularly around the average so trip counts and
/// divergence differ per thread; the cumulative sum clamps at nnz.
fn fill_csr(bufs: &mut Buffers, rp: usize, ci: usize, ncols: usize) {
    let n = bufs.bufs[rp].len() - 1;
    let nnz = bufs.bufs[ci].len();
    let avg = (nnz / n).max(1);
    let mut acc = 0usize;
    for i in 0..n {
        bufs.bufs[rp][i] = acc as f32;
        let deg = (i * 7 + 3) % (2 * avg + 1);
        acc = (acc + deg).min(nnz);
    }
    bufs.bufs[rp][n] = acc as f32;
    for e in 0..nnz {
        bufs.bufs[ci][e] = ((e * 11 + 5) % ncols) as f32;
    }
}

// ---- SPMV: y = A·x over CSR ----
// buffers: row_ptr[n+1], col_idx[nnz], vals[nnz], x[n], y[n]

fn spmv_host(bufs: &mut Buffers, _t: usize) {
    let ncols = bufs.bufs[3].len();
    fill_csr(bufs, 0, 1, ncols);
}

fn spmv_kernel(n: usize) -> Function {
    let mut b = KernelBuilder::new(
        "spmv_kernel",
        &[
            ("row_ptr", ptr()),
            ("col_idx", ptr()),
            ("vals", ptr()),
            ("x", ptr()),
            ("y", ptr()),
        ],
    );
    guard1(&mut b, n, |b, i| {
        // row extent comes out of memory: the trip count is invisible to
        // the analyzer (baseline-fallback territory)
        let rs = b.load(b.param(0), i);
        let start = b.fptosi(rs);
        let i1 = b.add(i, b.i(1));
        let re = b.load(b.param(0), i1);
        let end = b.fptosi(re);
        b.store(b.param(4), i, b.fc(0.0));
        b.for_loop("j", start, end, 1, |b, j| {
            let c = b.load(b.param(1), j);
            let ci = b.fptosi(c);
            let xv = b.load(b.param(3), ci); // gather
            let av = b.load(b.param(2), j);
            let prod = b.fmul(av, xv);
            rmw_add(b, b.param(4), i, prod);
        });
    });
    b.finish()
}

pub fn spmv() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let (n, nnz) = (d.n, d.m);
        let mut m = Module::new("SPMV");
        m.kernels.push(spmv_kernel(n));
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n, 1), repeat: 1 }],
            vec![n + 1, nnz, nnz, n, n],
            vec![4],
            1,
            spmv_host,
        )
    }
    Benchmark {
        name: "SPMV",
        family: "irregular",
        dims_full: Dims { n: 2048, m: 16384, tmax: 1 },
        dims_small: Dims { n: 24, m: 96, tmax: 1 },
        build,
    }
}

// ---- TREESUM: log2(n) halving reduction rounds ----
// buffers: data[n], stride[1]

fn treesum_host(bufs: &mut Buffers, t: usize) {
    let n = bufs.bufs[0].len();
    bufs.bufs[1][0] = (n >> (t + 1)) as f32;
}

fn treesum_kernel() -> Function {
    let mut b = KernelBuilder::new("treesum_kernel", &[("data", ptr()), ("stride", ptr())]);
    let i = b.gid(0);
    // the active-thread cutoff is a host scalar: broadcast load, then a
    // data-driven guard that leaves ever more of the warp idle
    let sv = b.load(b.param(1), b.i(0));
    let s = b.fptosi(sv);
    let c = b.icmp(CmpPred::Lt, i, s);
    b.if_then(c, |b| {
        let lo = b.load(b.param(0), i);
        let idx = b.add(i, s);
        let hi = b.load(b.param(0), idx); // stride read from memory
        let sum = b.fadd(lo, hi);
        b.store(b.param(0), i, sum);
    });
    b.finish()
}

pub fn treesum() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let rounds = n.trailing_zeros() as usize;
        let mut m = Module::new("TREESUM");
        m.kernels.push(treesum_kernel());
        finalize(
            m,
            v,
            vec![KernelInfo { grid: (n / 2, 1), repeat: 1 }],
            vec![n, 1],
            vec![0],
            rounds,
            treesum_host,
        )
    }
    Benchmark {
        name: "TREESUM",
        family: "irregular",
        dims_full: Dims { n: 65536, m: 1, tmax: 1 },
        dims_small: Dims { n: 32, m: 1, tmax: 1 },
        build,
    }
}

// ---- HISTO: atomic histogram, then an exclusive-ish scan over bins ----
// buffers: data[n], hist[bins], scan[bins]; dataflow k1 → k2 through hist

fn histo_host(bufs: &mut Buffers, _t: usize) {
    for x in bufs.bufs[1].iter_mut() {
        *x = 0.0;
    }
    for x in bufs.bufs[2].iter_mut() {
        *x = 0.0;
    }
}

fn histo_kernel(n: usize, bins: usize) -> Function {
    let mut b = KernelBuilder::new(
        "histo_kernel",
        &[("data", ptr()), ("hist", ptr()), ("scan", ptr())],
    );
    guard1(&mut b, n, |b, i| {
        // fill_value lands in [0.5, 1.49]: (v - 0.5) * bins hits every
        // bin in [0, bins-1], with hot bins contending on atom.add
        let v = b.load(b.param(0), i);
        let t = b.fadd(v, b.fc(-0.5));
        let scaled = b.fmul(t, b.fc(bins as f32));
        let bin = b.fptosi(scaled);
        b.atom_add(b.param(1), bin, b.fc(1.0));
    });
    b.finish()
}

fn scan_kernel(bins: usize) -> Function {
    let mut b = KernelBuilder::new(
        "scan_kernel",
        &[("data", ptr()), ("hist", ptr()), ("scan", ptr())],
    );
    guard1(&mut b, bins, |b, j| {
        // triangular inclusive scan accumulating through memory — the
        // licm-promotable idiom, so this kernel wants a very different
        // phase order than its atomic producer
        b.store(b.param(2), j, b.fc(0.0));
        let end = b.add(j, b.i(1));
        b.for_loop("k", b.i(0), end, 1, |b, k| {
            let h = b.load(b.param(1), k);
            rmw_add(b, b.param(2), j, h);
        });
    });
    b.finish()
}

pub fn histo() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let (n, bins) = (d.n, d.m);
        let mut m = Module::new("HISTO");
        m.kernels.push(histo_kernel(n, bins));
        m.kernels.push(scan_kernel(bins));
        finalize(
            m,
            v,
            vec![
                KernelInfo { grid: (n, 1), repeat: 1 },
                KernelInfo { grid: (bins, 1), repeat: 1 },
            ],
            vec![n, bins, bins],
            vec![1, 2],
            1,
            histo_host,
        )
    }
    Benchmark {
        name: "HISTO",
        family: "irregular",
        dims_full: Dims { n: 65536, m: 64, tmax: 1 },
        dims_small: Dims { n: 64, m: 16, tmax: 1 },
        build,
    }
}

// ---- BFS: frontier expand + ping-pong swap, tmax levels ----
// buffers: row_ptr[n+1], col_idx[nnz], dist[n], f_in[n], f_out[n], level[1]

fn bfs_host(bufs: &mut Buffers, t: usize) {
    if t == 0 {
        let n = bufs.bufs[2].len();
        fill_csr(bufs, 0, 1, n);
        for x in bufs.bufs[2].iter_mut() {
            *x = 0.0;
        }
        for (i, x) in bufs.bufs[3].iter_mut().enumerate() {
            *x = if i == 0 { 1.0 } else { 0.0 };
        }
        for x in bufs.bufs[4].iter_mut() {
            *x = 0.0;
        }
    }
    bufs.bufs[5][0] = (t + 1) as f32;
}

fn bfs_expand(n: usize) -> Function {
    let mut b = KernelBuilder::new(
        "bfs_expand",
        &[
            ("row_ptr", ptr()),
            ("col_idx", ptr()),
            ("dist", ptr()),
            ("f_in", ptr()),
            ("f_out", ptr()),
            ("level", ptr()),
        ],
    );
    guard1(&mut b, n, |b, i| {
        // frontier membership is data: most threads fall through, the
        // active ones walk a row of data-dependent length
        let fv = b.load(b.param(3), i);
        let fi = b.fptosi(fv);
        let active = b.icmp(CmpPred::Lt, b.i(0), fi);
        b.if_then(active, |b| {
            let rs = b.load(b.param(0), i);
            let start = b.fptosi(rs);
            let i1 = b.add(i, b.i(1));
            let re = b.load(b.param(0), i1);
            let end = b.fptosi(re);
            b.for_loop("e", start, end, 1, |b, e| {
                let cv = b.load(b.param(1), e);
                let v = b.fptosi(cv); // scattered neighbor index
                let lvl = b.load(b.param(5), b.i(0));
                b.atom_max(b.param(2), v, lvl);
                b.atom_max(b.param(4), v, b.fc(1.0));
            });
        });
    });
    b.finish()
}

fn bfs_swap(n: usize) -> Function {
    let mut b = KernelBuilder::new(
        "bfs_swap",
        &[
            ("row_ptr", ptr()),
            ("col_idx", ptr()),
            ("dist", ptr()),
            ("f_in", ptr()),
            ("f_out", ptr()),
            ("level", ptr()),
        ],
    );
    guard1(&mut b, n, |b, i| {
        let fo = b.load(b.param(4), i);
        b.store(b.param(3), i, fo);
        b.store(b.param(4), i, b.fc(0.0));
    });
    b.finish()
}

pub fn bfs() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let (n, nnz) = (d.n, d.m);
        let mut m = Module::new("BFS");
        m.kernels.push(bfs_expand(n));
        m.kernels.push(bfs_swap(n));
        finalize(
            m,
            v,
            vec![
                KernelInfo { grid: (n, 1), repeat: 1 },
                KernelInfo { grid: (n, 1), repeat: 1 },
            ],
            vec![n + 1, nnz, n, n, n, 1],
            vec![2],
            d.tmax,
            bfs_host,
        )
    }
    Benchmark {
        name: "BFS",
        family: "irregular",
        dims_full: Dims { n: 4096, m: 32768, tmax: 8 },
        dims_small: Dims { n: 24, m: 72, tmax: 3 },
        build,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::{execute, init_buffers, outputs_match};

    /// The executor's atomics against a scalar host reference: HISTO's
    /// bin counts must equal a sequential histogram of the same data.
    #[test]
    fn histogram_matches_scalar_reference() {
        let b = histo();
        let built = b.build_small(Variant::OpenCl);
        let mut bufs = init_buffers(&built);
        execute(&built, &mut bufs, 200_000_000).unwrap();
        let bins = built.buf_sizes[1];
        let mut want = vec![0.0f32; bins];
        for i in 0..built.buf_sizes[0] {
            let v = crate::bench_suite::fill_value(0, i);
            let bin = ((v - 0.5) * bins as f32) as usize;
            want[bin] += 1.0;
        }
        assert_eq!(bufs.bufs[1], want, "atom.add disagrees with scalar histogram");
        // and the scan kernel consumed what the histogram produced
        let total: f32 = want.iter().sum();
        assert_eq!(bufs.bufs[2][bins - 1], total);
    }

    /// TREESUM's halving rounds against a straight sum.
    #[test]
    fn tree_reduction_sums_exactly() {
        let b = treesum();
        let built = b.build_small(Variant::OpenCl);
        let mut bufs = init_buffers(&built);
        let want: f32 = bufs.bufs[0].iter().sum();
        execute(&built, &mut bufs, 200_000_000).unwrap();
        assert!((bufs.bufs[0][0] - want).abs() / want < 1e-4);
    }

    /// SPMV against a scalar CSR walk over the same host-built structure.
    #[test]
    fn spmv_matches_scalar_reference() {
        let b = spmv();
        let built = b.build_small(Variant::OpenCl);
        let mut bufs = init_buffers(&built);
        let mut want = init_buffers(&built);
        execute(&built, &mut bufs, 200_000_000).unwrap();
        // host reference on the same deterministic structure
        spmv_host(&mut want, 0);
        let n = built.buf_sizes[4];
        for i in 0..n {
            let start = want.bufs[0][i] as usize;
            let end = want.bufs[0][i + 1] as usize;
            let mut acc = 0.0f32;
            for j in start..end {
                let c = want.bufs[1][j] as usize;
                acc += want.bufs[2][j] * want.bufs[3][c];
            }
            want.bufs[4][i] = acc;
        }
        assert!(
            outputs_match(&built, &bufs, &want, 0.01),
            "gathered SpMV diverges from scalar reference"
        );
    }

    /// BFS runs, stays deterministic, and actually expands the frontier.
    #[test]
    fn bfs_expands_frontier_deterministically() {
        let b = bfs();
        let built = b.build_small(Variant::OpenCl);
        let mut b1 = init_buffers(&built);
        let mut b2 = init_buffers(&built);
        execute(&built, &mut b1, 200_000_000).unwrap();
        execute(&built, &mut b2, 200_000_000).unwrap();
        assert_eq!(b1.bufs, b2.bufs);
        let touched = b1.bufs[2].iter().filter(|&&d| d > 0.0).count();
        assert!(touched > 1, "expansion reached {touched} vertices");
    }
}
