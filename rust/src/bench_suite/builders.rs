//! Shared helpers for the benchmark builders.

use crate::ir::{AddrSpace, CmpPred, KernelBuilder, Ty, Value};

pub(crate) const ALPHA: f32 = 1.5;
pub(crate) const BETA: f32 = 1.2;

/// All benchmark buffers are f32 global arrays.
pub(crate) fn ptr() -> Ty {
    Ty::Ptr(AddrSpace::Global)
}

/// Row-major 2D index `i*n + j` (fresh arithmetic per use — the naive
/// frontend shape; the backend's machine CSE dedups what ptxas would).
pub(crate) fn idx2(b: &mut KernelBuilder, i: Value, j: Value, n: usize) -> Value {
    let t = b.mul(i, b.i(n as i64));
    b.add(t, j)
}

/// 3D index `i*n*n + j*n + k`.
pub(crate) fn idx3(b: &mut KernelBuilder, i: Value, j: Value, k: Value, n: usize) -> Value {
    let t1 = b.mul(i, b.i((n * n) as i64));
    let t2 = b.mul(j, b.i(n as i64));
    let s = b.add(t1, t2);
    b.add(s, k)
}

/// 2D guard `gid.1 < rows && gid.0 < cols` around `body`.
pub(crate) fn guard2(
    b: &mut KernelBuilder,
    rows: usize,
    cols: usize,
    body: impl FnOnce(&mut KernelBuilder, Value, Value),
) {
    let i = b.gid(1);
    let j = b.gid(0);
    let ci = b.icmp(CmpPred::Lt, i, b.i(rows as i64));
    let cj = b.icmp(CmpPred::Lt, j, b.i(cols as i64));
    let c = b.and(ci, cj);
    b.if_then(c, |b| body(b, i, j));
}

/// 1D guard `gid.0 < n`.
pub(crate) fn guard1(
    b: &mut KernelBuilder,
    n: usize,
    body: impl FnOnce(&mut KernelBuilder, Value),
) {
    let i = b.gid(0);
    let c = b.icmp(CmpPred::Lt, i, b.i(n as i64));
    b.if_then(c, |b| body(b, i));
}

/// `buf[idx] op= value` read-modify-write through memory (the PolyBench
/// accumulation idiom that licm promotes).
pub(crate) fn rmw_add(b: &mut KernelBuilder, buf: Value, idx: Value, v: Value) {
    let cur = b.load(buf, idx);
    let nxt = b.fadd(cur, v);
    b.store(buf, idx, nxt);
}
