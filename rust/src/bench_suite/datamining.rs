//! Data-mining benchmarks: CORR and COVAR.
//!
//! Faithful to the PolyBench/GPU structures that matter for the paper:
//! * the correlation/covariance kernel is a per-thread triangular double
//!   loop whose innermost i-loop accumulates into `symmat[j1*M+j2]`
//!   through global memory — the biggest promotion win in Fig. 2
//!   (CORR 5.36×, COVAR similar);
//! * CORR's inner loop starts at `j2 = j1+1` (diagonal excluded), while
//!   COVAR's starts at `j2 = j1` (diagonal *included*) — the distinction
//!   that makes the dse bug model (#1, symmetric-index screen) a genuine
//!   COVAR-only miscompile.

use super::builders::*;
use super::{cudaify, set_innermost_unroll, Benchmark, BuiltBench, Dims, KernelInfo, Variant};
use crate::ir::{CmpPred, KernelBuilder, Module, Ty};

const EPS: f32 = 0.005;

fn finalize(mut module: Module, v: Variant, kernels: Vec<KernelInfo>, buf_sizes: Vec<usize>, outputs: Vec<usize>) -> BuiltBench {
    match v {
        Variant::OpenCl => {
            for f in &mut module.kernels {
                set_innermost_unroll(f, 2);
            }
        }
        Variant::Cuda => cudaify(&mut module, 8),
    }
    BuiltBench::simple(module, kernels, buf_sizes, outputs)
}

/// mean[j] = (Σ_i data[i*n+j]) / n
fn mean_kernel(plist: &[(&str, Ty)], n: usize, data: usize, mean: usize) -> crate::ir::Function {
    let mut b = KernelBuilder::new("mean_kernel", plist);
    guard1(&mut b, n, |b, j| {
        b.store(b.param(mean), j, b.fc(0.0));
        let nn = b.i(n as i64);
        b.for_loop("i", b.i(0), nn, 1, |b, i| {
            let didx = idx2(b, i, j, n);
            let dv = b.load(b.param(data), didx);
            rmw_add(b, b.param(mean), j, dv);
        });
        let acc = b.load(b.param(mean), j);
        let avg = b.fdiv(acc, b.fc(n as f32));
        b.store(b.param(mean), j, avg);
    });
    b.finish()
}

pub fn corr() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["data", "mean", "stddev", "symmat"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("CORR");
        m.kernels.push(mean_kernel(&plist, n, 0, 1));
        // std_kernel: stddev[j] = sqrt(Σ (d-mean)²/n), clamped to 1 at eps
        {
            let mut b = KernelBuilder::new("std_kernel", &plist);
            guard1(&mut b, n, |b, j| {
                b.store(b.param(2), j, b.fc(0.0));
                let nn = b.i(n as i64);
                b.for_loop("i", b.i(0), nn, 1, |b, i| {
                    let didx = idx2(b, i, j, n);
                    let dv = b.load(b.param(0), didx);
                    let mv = b.load(b.param(1), j);
                    let diff = b.fsub(dv, mv);
                    let sq = b.fmul(diff, diff);
                    rmw_add(b, b.param(2), j, sq);
                });
                let acc = b.load(b.param(2), j);
                let varv = b.fdiv(acc, b.fc(n as f32));
                let sd = b.fsqrt(varv);
                // stddev <= eps ? 1.0 : stddev  — a real branch, as in the
                // original kernel source
                let c = b.fcmp(CmpPred::Le, sd, b.fc(EPS));
                let sel = b.if_then_else_val(c, |b| b.fc(1.0), |_b| sd);
                b.store(b.param(2), j, sel);
            });
            m.kernels.push(b.finish());
        }
        // reduce_kernel: data = (data - mean[j]) / (sqrt(n)·stddev[j])
        {
            let mut b = KernelBuilder::new("reduce_kernel", &plist);
            guard2(&mut b, n, n, |b, i, j| {
                let didx = idx2(b, i, j, n);
                let dv = b.load(b.param(0), didx);
                let mv = b.load(b.param(1), j);
                let centered = b.fsub(dv, mv);
                let sv = b.load(b.param(2), j);
                let denom = b.fmul(sv, b.fc((n as f32).sqrt()));
                let scaled = b.fdiv(centered, denom);
                b.store(b.param(0), didx, scaled);
            });
            m.kernels.push(b.finish());
        }
        // corr_kernel: j1 = gid, triangular, diagonal EXCLUDED (j2=j1+1)
        {
            let mut b = KernelBuilder::new("corr_kernel", &plist);
            let nm1 = n.saturating_sub(1);
            guard1(&mut b, nm1, |b, j1| {
                let diag = idx2(b, j1, j1, n);
                b.store(b.param(3), diag, b.fc(1.0));
                let start = b.add(j1, b.i(1));
                let nn = b.i(n as i64);
                b.for_loop("j2", start, nn, 1, |b, j2| {
                    let s12 = idx2(b, j1, j2, n);
                    b.store(b.param(3), s12, b.fc(0.0));
                    let nn2 = b.i(n as i64);
                    b.for_loop("i", b.i(0), nn2, 1, |b, i| {
                        let d1 = idx2(b, i, j1, n);
                        let d2 = idx2(b, i, j2, n);
                        let v1 = b.load(b.param(0), d1);
                        let v2 = b.load(b.param(0), d2);
                        let prod = b.fmul(v1, v2);
                        rmw_add(b, b.param(3), s12, prod);
                    });
                    let s21 = idx2(b, j2, j1, n);
                    let v = b.load(b.param(3), s12);
                    b.store(b.param(3), s21, v);
                });
            });
            m.kernels.push(b.finish());
        }
        finalize(
            m,
            v,
            vec![
                KernelInfo { grid: (n, 1), repeat: 1 },
                KernelInfo { grid: (n, 1), repeat: 1 },
                KernelInfo { grid: (n, n), repeat: 1 },
                KernelInfo { grid: (n.saturating_sub(1), 1), repeat: 1 },
            ],
            vec![n * n, n, n, n * n],
            vec![3],
        )
    }
    Benchmark {
        name: "CORR",
        family: "data-mining",
        dims_full: Dims { n: 2048, m: 2048, tmax: 1 },
        dims_small: Dims { n: 10, m: 10, tmax: 1 },
        build,
    }
}

pub fn covar() -> Benchmark {
    fn build(d: &Dims, v: Variant) -> BuiltBench {
        let n = d.n;
        let params = &["data", "mean", "symmat"];
        let plist: Vec<(&str, Ty)> = params.iter().map(|&p| (p, ptr())).collect();
        let mut m = Module::new("COVAR");
        m.kernels.push(mean_kernel(&plist, n, 0, 1));
        // reduce_kernel: data -= mean[j]
        {
            let mut b = KernelBuilder::new("reduce_kernel", &plist);
            guard2(&mut b, n, n, |b, i, j| {
                let didx = idx2(b, i, j, n);
                let dv = b.load(b.param(0), didx);
                let mv = b.load(b.param(1), j);
                let centered = b.fsub(dv, mv);
                b.store(b.param(0), didx, centered);
            });
            m.kernels.push(b.finish());
        }
        // covar_kernel: diagonal INCLUDED (j2 starts at j1)
        {
            let mut b = KernelBuilder::new("covar_kernel", &plist);
            guard1(&mut b, n, |b, j1| {
                let nn = b.i(n as i64);
                b.for_loop("j2", j1, nn, 1, |b, j2| {
                    let s12 = idx2(b, j1, j2, n);
                    b.store(b.param(2), s12, b.fc(0.0));
                    let nn2 = b.i(n as i64);
                    b.for_loop("i", b.i(0), nn2, 1, |b, i| {
                        let d1 = idx2(b, i, j1, n);
                        let d2 = idx2(b, i, j2, n);
                        let v1 = b.load(b.param(0), d1);
                        let v2 = b.load(b.param(0), d2);
                        let prod = b.fmul(v1, v2);
                        rmw_add(b, b.param(2), s12, prod);
                    });
                    let s21 = idx2(b, j2, j1, n);
                    let v = b.load(b.param(2), s12);
                    b.store(b.param(2), s21, v);
                });
            });
            m.kernels.push(b.finish());
        }
        finalize(
            m,
            v,
            vec![
                KernelInfo { grid: (n, 1), repeat: 1 },
                KernelInfo { grid: (n, n), repeat: 1 },
                KernelInfo { grid: (n, 1), repeat: 1 },
            ],
            vec![n * n, n, n * n],
            vec![2],
        )
    }
    Benchmark {
        name: "COVAR",
        family: "data-mining",
        dims_full: Dims { n: 2048, m: 2048, tmax: 1 },
        dims_small: Dims { n: 10, m: 10, tmax: 1 },
        build,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corr_diagonal_excluded_covar_included() {
        use crate::ir::printer::print_function;
        // structural check of the j2 loop start: CORR's preheader feeds
        // `j1+1`, COVAR's feeds `j1` directly
        let c = corr().build_small(Variant::OpenCl);
        let text = print_function(c.module.kernels.last().unwrap());
        assert!(text.contains("j2"), "{text}");
        let v = covar().build_small(Variant::OpenCl);
        let textv = print_function(v.module.kernels.last().unwrap());
        assert!(textv.contains("j2"));
    }
}
