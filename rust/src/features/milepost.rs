//! MILEPOST-style static feature extraction (55 features, §4.1).
//!
//! The paper feeds the OpenCL C through MILEPOST GCC's ICI extractor;
//! our equivalent reads the same class of properties — basic-block shape
//! counts, instruction mix, phi statistics, loop structure, memory
//! access shape — off the unoptimized kernel IR. Feature indices are
//! stable and documented here; no feature selection is applied (§4.1:
//! "all 55 code features ... are represented").

use crate::analysis::AffineCtx;
use crate::ir::{Function, Module, Op, Value};

pub const NUM_FEATURES: usize = 55;

pub type FeatureVector = [f64; NUM_FEATURES];

/// Human-readable names, index-aligned with the vector.
pub const FEATURE_NAMES: [&str; NUM_FEATURES] = [
    "ft01_num_blocks",
    "ft02_blocks_single_succ",
    "ft03_blocks_two_succ",
    "ft04_blocks_no_succ",
    "ft05_blocks_single_pred",
    "ft06_blocks_two_pred",
    "ft07_blocks_multi_pred",
    "ft08_blocks_1pred_1succ",
    "ft09_blocks_1pred_2succ",
    "ft10_blocks_2pred_1succ",
    "ft11_cfg_edges",
    "ft12_critical_edges",
    "ft13_num_insts",
    "ft14_avg_insts_per_block",
    "ft15_num_loads",
    "ft16_num_stores",
    "ft17_load_store_ratio",
    "ft18_int_arith",
    "ft19_fp_arith",
    "ft20_fp_mul",
    "ft21_fp_div",
    "ft22_fp_special",
    "ft23_int_mul",
    "ft24_shifts",
    "ft25_logic_ops",
    "ft26_casts",
    "ft27_ptr_arith",
    "ft28_icmp",
    "ft29_fcmp",
    "ft30_select",
    "ft31_phi_nodes",
    "ft32_avg_phi_args",
    "ft33_blocks_with_phi",
    "ft34_max_phi_in_block",
    "ft35_cond_branches",
    "ft36_uncond_branches",
    "ft37_num_loops",
    "ft38_max_loop_depth",
    "ft39_avg_loop_depth",
    "ft40_loops_with_const_bounds",
    "ft41_innermost_loops",
    "ft42_stores_in_loops",
    "ft43_loads_in_loops",
    "ft44_accum_stores", // store to loop-invariant address in a loop
    "ft45_coalesced_accesses",
    "ft46_strided_accesses",
    "ft47_broadcast_accesses",
    "ft48_num_kernels",
    "ft49_num_params",
    "ft50_num_buffers",
    "ft51_gid_dims_used",
    "ft52_guard_depth",
    "ft53_fp_consts",
    "ft54_int_consts",
    "ft55_symmetric_index_pairs", // A[i*M+j] with matching A[j*M+i]
];

/// Extract the 55-feature vector from a module (summed over kernels).
pub fn extract_features(m: &Module) -> FeatureVector {
    let mut ft = [0.0f64; NUM_FEATURES];
    for f in &m.kernels {
        extract_function(m, f, &mut ft);
    }
    ft[47] = m.kernels.len() as f64;
    // derived averages
    if ft[0] > 0.0 {
        ft[13] = ft[12] / ft[0]; // insts per block
    }
    if ft[15] > 0.0 {
        ft[16] = ft[14] / ft[15]; // load/store ratio
    }
    if ft[30] > 0.0 {
        ft[31] /= ft[30]; // avg phi args
    }
    if ft[36] > 0.0 {
        ft[38] /= ft[36]; // avg loop depth
    }
    ft
}

fn extract_function(m: &Module, f: &Function, ft: &mut FeatureVector) {
    let (_dt, lf) = crate::passes::analyses::analyses_of(f);
    let mut live_blocks = 0.0;
    for bb in f.block_ids() {
        let blk = f.block(bb);
        if blk.insts.is_empty() {
            continue;
        }
        live_blocks += 1.0;
        let (np, ns) = (blk.preds.len(), blk.succs.len());
        ft[0] += 1.0;
        match ns {
            1 => ft[1] += 1.0,
            2 => ft[2] += 1.0,
            0 => ft[3] += 1.0,
            _ => {}
        }
        match np {
            1 => ft[4] += 1.0,
            2 => ft[5] += 1.0,
            n if n > 2 => ft[6] += 1.0,
            _ => {}
        }
        if np == 1 && ns == 1 {
            ft[7] += 1.0;
        }
        if np == 1 && ns == 2 {
            ft[8] += 1.0;
        }
        if np == 2 && ns == 1 {
            ft[9] += 1.0;
        }
        ft[10] += ns as f64;
        // critical edge: multi-succ source to multi-pred target
        for &s in &blk.succs {
            if ns > 1 && f.block(s).preds.len() > 1 {
                ft[11] += 1.0;
            }
        }
        let mut phis_here = 0.0;
        for &i in &blk.insts {
            let inst = f.inst(i);
            if inst.is_nop() {
                continue;
            }
            ft[12] += 1.0;
            match inst.op {
                Op::Load => ft[14] += 1.0,
                Op::Store => ft[15] += 1.0,
                Op::Add | Op::Sub => ft[17] += 1.0,
                Op::FAdd | Op::FSub => ft[18] += 1.0,
                Op::FMul => {
                    ft[18] += 1.0;
                    ft[19] += 1.0;
                }
                Op::FDiv => ft[20] += 1.0,
                Op::FSqrt | Op::FExp | Op::FAbs | Op::FNeg => ft[21] += 1.0,
                Op::Mul | Op::SDiv | Op::SRem => ft[22] += 1.0,
                Op::Shl | Op::AShr => ft[23] += 1.0,
                Op::And | Op::Or | Op::Xor => ft[24] += 1.0,
                Op::Sext | Op::Trunc | Op::SiToFp | Op::FpToSi => ft[25] += 1.0,
                Op::PtrAdd => ft[26] += 1.0,
                Op::ICmp(_) => ft[27] += 1.0,
                Op::FCmp(_) => ft[28] += 1.0,
                Op::Select => ft[29] += 1.0,
                Op::Phi => {
                    ft[30] += 1.0;
                    ft[31] += inst.args().len() as f64;
                    phis_here += 1.0;
                }
                Op::CondBr => ft[34] += 1.0,
                Op::Br => ft[35] += 1.0,
                _ => {}
            }
            for &a in inst.args() {
                match a {
                    Value::ImmF(_) => ft[52] += 1.0,
                    Value::ImmI(_) => ft[53] += 1.0,
                    Value::GlobalId(d) => ft[50] = ft[50].max(1.0 + d as f64),
                    _ => {}
                }
            }
        }
        if phis_here > 0.0 {
            ft[32] += 1.0;
            ft[33] = ft[33].max(phis_here);
        }
    }
    let _ = live_blocks;
    // loops
    ft[36] += lf.loops.len() as f64;
    for (li, l) in lf.loops.iter().enumerate() {
        ft[37] = ft[37].max(l.depth as f64);
        ft[38] += l.depth as f64;
        // const bound: header cmp rhs is an immediate
        if let Some(term) = f.terminator(l.header) {
            if f.inst(term).op == Op::CondBr {
                if let Some(ci) = f.inst(term).args()[0].as_inst() {
                    if matches!(f.inst(ci).op, Op::ICmp(_))
                        && matches!(f.inst(ci).args()[1], Value::ImmI(_))
                    {
                        ft[39] += 1.0;
                    }
                }
            }
        }
        let is_innermost = !lf.loops.iter().enumerate().any(|(oi, o)| {
            oi != li && o.depth > l.depth && o.blocks.iter().all(|b| l.blocks.contains(b))
        });
        if is_innermost {
            ft[40] += 1.0;
        }
        // memory in loops + accumulation pattern: a store whose *address
        // affine* is free of this loop's induction variables (the
        // `c[i*NJ+j] += …` idiom; the address chain itself is recomputed
        // per iteration in the naive IR, so a def-location check would
        // miss it)
        let ivs: Vec<Value> = {
            let mut cx = AffineCtx::new(f);
            f.block(l.header)
                .insts
                .iter()
                .filter(|&&i| f.inst(i).op == Op::Phi)
                .map(|&i| Value::Inst(i))
                .filter(|&v| cx.as_induction(v).is_some())
                .collect()
        };
        for &bb in &l.blocks {
            for &i in &f.block(bb).insts {
                let inst = f.inst(i);
                match inst.op {
                    Op::Store => {
                        ft[41] += 1.0;
                        let mut cx = AffineCtx::new(f);
                        let loc = crate::analysis::MemLoc::resolve(&mut cx, inst.args()[0]);
                        if let Some(off) = loc.off {
                            if ivs.iter().all(|&iv| off.coeff(iv) == 0) {
                                ft[43] += 1.0;
                            }
                        }
                    }
                    Op::Load => ft[42] += 1.0,
                    _ => {}
                }
            }
        }
    }
    // access-shape counts
    let mut sym_pairs = 0.0;
    let mut offs: Vec<(u16, crate::analysis::Affine)> = Vec::new();
    for bb in f.block_ids() {
        for &i in &f.block(bb).insts {
            let inst = f.inst(i);
            if !inst.op.is_memory() {
                continue;
            }
            match crate::codegen::ptx::classify(f, m, inst.args()[0]) {
                crate::codegen::MemClass::Coalesced => ft[44] += 1.0,
                crate::codegen::MemClass::Strided => ft[45] += 1.0,
                crate::codegen::MemClass::Broadcast => ft[46] += 1.0,
                _ => {}
            }
            let mut cx = AffineCtx::new(f);
            let loc = crate::analysis::MemLoc::resolve(&mut cx, inst.args()[0]);
            if let (crate::analysis::Root::Param(p), Some(off)) = (loc.root, loc.off) {
                offs.push((p, off));
            }
        }
    }
    // symmetric pair detection: offsets (a·x + b·y) and (b·x + a·y)
    for i in 0..offs.len() {
        for j in (i + 1)..offs.len() {
            if offs[i].0 != offs[j].0 {
                continue;
            }
            let (a, b) = (&offs[i].1, &offs[j].1);
            if a != b && a.terms.len() == 2 && b.terms.len() == 2 {
                let swapped = a.terms[0].1 == b.terms[1].1
                    && a.terms[1].1 == b.terms[0].1
                    && a.terms[0].0 == b.terms[0].0
                    && a.terms[1].0 == b.terms[1].0
                    && a.konst == b.konst;
                if swapped {
                    sym_pairs += 1.0;
                }
            }
        }
    }
    ft[54] += sym_pairs;
    ft[48] += f.params.len() as f64;
    ft[49] += f.params.iter().filter(|p| p.ty.is_ptr()).count() as f64;
    // guard depth: conditional branches outside loops
    let in_loop_blocks: std::collections::HashSet<_> =
        lf.loops.iter().flat_map(|l| l.blocks.iter().copied()).collect();
    for bb in f.block_ids() {
        if in_loop_blocks.contains(&bb) {
            continue;
        }
        if let Some(t) = f.terminator(bb) {
            if f.inst(t).op == Op::CondBr {
                ft[51] += 1.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::{all_benchmarks, benchmark_by_name, Variant};

    #[test]
    fn vectors_are_finite_and_nonzero() {
        for b in all_benchmarks() {
            let built = b.build_small(Variant::OpenCl);
            let ft = extract_features(&built.module);
            assert!(ft.iter().all(|x| x.is_finite()), "{}", b.name);
            assert!(ft.iter().any(|&x| x > 0.0), "{}", b.name);
        }
    }

    #[test]
    fn distinguishes_benchmarks() {
        let g = benchmark_by_name("GEMM").unwrap().build_small(Variant::OpenCl);
        let c = benchmark_by_name("2DCONV").unwrap().build_small(Variant::OpenCl);
        let fg = extract_features(&g.module);
        let fc = extract_features(&c.module);
        assert_ne!(fg.to_vec(), fc.to_vec());
        // conv has no loops; gemm does
        assert_eq!(fc[36], 0.0);
        assert!(fg[36] > 0.0);
        // gemm has the accumulation-store feature
        assert!(fg[43] > 0.0);
        assert_eq!(fc[43], 0.0);
    }

    #[test]
    fn symmetric_pairs_found_in_corr_like() {
        let c = benchmark_by_name("CORR").unwrap().build_small(Variant::OpenCl);
        let ft = extract_features(&c.module);
        assert!(ft[54] > 0.0, "corr kernel writes symmat[j1][j2] and symmat[j2][j1]");
    }

    #[test]
    fn names_count_matches() {
        assert_eq!(FEATURE_NAMES.len(), NUM_FEATURES);
    }
}
