//! §4: feature-based phase-order suggestion.
//!
//! * [`milepost`] — 55 MILEPOST-style static code features extracted from
//!   the unoptimized OpenCL IR (the paper uses MILEPOST GCC's extractor
//!   on the OpenCL C; ours reads the same program properties off the IR).
//! * [`knn`] — cosine-similarity k-NN over feature vectors.
//! * [`itergraph`] — the IterGraph comparator [12]: a pass-transition
//!   graph built from the reference sequences, sampled by weighted walks.

pub mod itergraph;
pub mod knn;
pub mod milepost;

pub use itergraph::IterGraph;
pub use knn::{cosine_similarity, rank_by_similarity, rank_neighbors};
pub use milepost::{extract_features, FeatureVector, NUM_FEATURES};
