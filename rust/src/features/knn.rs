//! Cosine-similarity k-NN over feature vectors (§4.2: "we use the cosine
//! distance between feature vectors ... as metric of similarity").

use super::milepost::FeatureVector;

pub fn cosine_similarity(a: &FeatureVector, b: &FeatureVector) -> f64 {
    let mut dot = 0.0;
    let mut na = 0.0;
    let mut nb = 0.0;
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na += a[i] * a[i];
        nb += b[i] * b[i];
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Rank reference entries by descending similarity to the query.
/// Returns `(index into refs, cosine similarity)` pairs so consumers
/// (the kNN-seeded search strategy, the fig7 report) can surface the
/// similarity without recomputing it.
pub fn rank_by_similarity(
    query: &FeatureVector,
    refs: &[(String, FeatureVector)],
) -> Vec<(usize, f64)> {
    let sims: Vec<f64> = refs
        .iter()
        .map(|(_, v)| cosine_similarity(query, v))
        .collect();
    let mut idx: Vec<usize> = (0..refs.len()).collect();
    // stable order on ties for reproducibility
    idx.sort_by(|&a, &b| {
        sims[b]
            .partial_cmp(&sims[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.into_iter().map(|i| (i, sims[i])).collect()
}

/// Leave-one-out neighbor ranking (§4.2): rank every entry except `qi`
/// by descending similarity to entry `qi`, returning `(global index
/// into feats, similarity)` pairs. The one implementation of the
/// protocol shared by the kNN-seeded search strategy and the fig7
/// driver — keep them agreeing by construction.
pub fn rank_neighbors(qi: usize, feats: &[(String, FeatureVector)]) -> Vec<(usize, f64)> {
    let refs: Vec<(String, FeatureVector)> = feats
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != qi)
        .map(|(_, x)| x.clone())
        .collect();
    // ref indices skip qi: everything at or past it shifts up by one
    rank_by_similarity(&feats[qi].1, &refs)
        .into_iter()
        .map(|(ri, sim)| (if ri < qi { ri } else { ri + 1 }, sim))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::milepost::NUM_FEATURES;

    fn v(f: impl Fn(usize) -> f64) -> FeatureVector {
        let mut out = [0.0; NUM_FEATURES];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        out
    }

    #[test]
    fn identical_vectors_sim_one() {
        let a = v(|i| (i + 1) as f64);
        assert!((cosine_similarity(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_vectors_sim_zero() {
        let a = v(|i| if i == 0 { 1.0 } else { 0.0 });
        let b = v(|i| if i == 1 { 1.0 } else { 0.0 });
        assert_eq!(cosine_similarity(&a, &b), 0.0);
    }

    #[test]
    fn ranking_prefers_similar() {
        let q = v(|i| (i % 5) as f64);
        let close = v(|i| (i % 5) as f64 + 0.01);
        let far = v(|i| ((i * 13) % 7) as f64);
        let refs = vec![("far".to_string(), far), ("close".to_string(), close)];
        let order = rank_by_similarity(&q, &refs);
        assert_eq!(order[0].0, 1);
        // the returned similarities are the cosine similarities, in
        // descending order
        assert!((order[0].1 - cosine_similarity(&q, &refs[1].1)).abs() < 1e-15);
        assert!((order[1].1 - cosine_similarity(&q, &refs[0].1)).abs() < 1e-15);
        assert!(order[0].1 >= order[1].1);
    }

    #[test]
    fn leave_one_out_ranking_returns_global_indices() {
        let q = v(|i| (i % 5) as f64);
        let close = v(|i| (i % 5) as f64 + 0.01);
        let far = v(|i| ((i * 13) % 7) as f64);
        let feats = vec![
            ("far".to_string(), far),
            ("query".to_string(), q),
            ("close".to_string(), close),
        ];
        // query sits at index 1: neighbors are 0 ("far") and 2 ("close")
        let order = rank_neighbors(1, &feats);
        assert_eq!(order.len(), 2);
        assert_eq!(order[0].0, 2, "nearest neighbor is the global index of close");
        assert_eq!(order[1].0, 0);
        assert!((order[0].1 - cosine_similarity(&feats[1].1, &feats[2].1)).abs() < 1e-15);
        assert!(!order.iter().any(|&(gi, _)| gi == 1), "query never ranks itself");
    }

    #[test]
    fn real_benchmarks_cluster_by_family() {
        use crate::bench_suite::{benchmark_by_name, Variant};
        use crate::features::milepost::extract_features;
        let f = |n: &str| {
            extract_features(
                &benchmark_by_name(n).unwrap().build_small(Variant::OpenCl).module,
            )
        };
        let gemm = f("GEMM");
        let syrk = f("SYRK");
        let conv = f("2DCONV");
        // GEMM should be closer to SYRK (same shape) than to 2DCONV
        assert!(
            cosine_similarity(&gemm, &syrk) > cosine_similarity(&gemm, &conv),
            "gemm~syrk {} vs gemm~conv {}",
            cosine_similarity(&gemm, &syrk),
            cosine_similarity(&gemm, &conv)
        );
    }
}
