//! Cosine-similarity k-NN over feature vectors (§4.2: "we use the cosine
//! distance between feature vectors ... as metric of similarity").

use super::milepost::FeatureVector;

pub fn cosine_similarity(a: &FeatureVector, b: &FeatureVector) -> f64 {
    let mut dot = 0.0;
    let mut na = 0.0;
    let mut nb = 0.0;
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na += a[i] * a[i];
        nb += b[i] * b[i];
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Rank reference entries by descending similarity to the query.
/// Returns indices into `refs`.
pub fn rank_by_similarity(query: &FeatureVector, refs: &[(String, FeatureVector)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..refs.len()).collect();
    let mut sims: Vec<f64> = refs
        .iter()
        .map(|(_, v)| cosine_similarity(query, v))
        .collect();
    // stable order on ties for reproducibility
    idx.sort_by(|&a, &b| {
        sims[b]
            .partial_cmp(&sims[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let _ = &mut sims;
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::milepost::NUM_FEATURES;

    fn v(f: impl Fn(usize) -> f64) -> FeatureVector {
        let mut out = [0.0; NUM_FEATURES];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        out
    }

    #[test]
    fn identical_vectors_sim_one() {
        let a = v(|i| (i + 1) as f64);
        assert!((cosine_similarity(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_vectors_sim_zero() {
        let a = v(|i| if i == 0 { 1.0 } else { 0.0 });
        let b = v(|i| if i == 1 { 1.0 } else { 0.0 });
        assert_eq!(cosine_similarity(&a, &b), 0.0);
    }

    #[test]
    fn ranking_prefers_similar() {
        let q = v(|i| (i % 5) as f64);
        let close = v(|i| (i % 5) as f64 + 0.01);
        let far = v(|i| ((i * 13) % 7) as f64);
        let refs = vec![("far".to_string(), far), ("close".to_string(), close)];
        let order = rank_by_similarity(&q, &refs);
        assert_eq!(order[0], 1);
    }

    #[test]
    fn real_benchmarks_cluster_by_family() {
        use crate::bench_suite::{benchmark_by_name, Variant};
        use crate::features::milepost::extract_features;
        let f = |n: &str| {
            extract_features(
                &benchmark_by_name(n).unwrap().build_small(Variant::OpenCl).module,
            )
        };
        let gemm = f("GEMM");
        let syrk = f("SYRK");
        let conv = f("2DCONV");
        // GEMM should be closer to SYRK (same shape) than to 2DCONV
        assert!(
            cosine_similarity(&gemm, &syrk) > cosine_similarity(&gemm, &conv),
            "gemm~syrk {} vs gemm~conv {}",
            cosine_similarity(&gemm, &syrk),
            cosine_similarity(&gemm, &conv)
        );
    }
}
