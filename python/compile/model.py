"""L2 — JAX golden models of all 15 PolyBench/GPU benchmarks.

Each model replays, in JAX, exactly what the rust benchmark's kernel
sequence computes at *validation* (small) size — same deterministic
buffer initialization (`fill`, mirroring `bench_suite::fill_value`), same
kernel order, same guard semantics, same untouched-border behaviour.
These are the independent references the DSE validator compares candidate
compilations against (paper §2.4's CPU reference, here served through
PJRT from AOT artifacts).

The matmul family routes its contraction through the L1 Pallas kernel
(`kernels.matmul`), so the artifact HLO genuinely contains the lowered
kernel. Python never runs at DSE time: `aot.py` lowers every model once.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul

ALPHA = 1.5
BETA = 1.2
EPS = 0.005

# must mirror rust/src/bench_suite/*.rs dims_small
DIMS = {
    "2DCONV": dict(n=16),
    "3DCONV": dict(n=8),
    "2MM": dict(n=12),
    "3MM": dict(n=10),
    "ATAX": dict(n=24),
    "BICG": dict(n=24),
    "CORR": dict(n=10),
    "COVAR": dict(n=10),
    "FDTD-2D": dict(n=10, tmax=3),
    "GEMM": dict(n=12),
    "GESUMMV": dict(n=20),
    "GRAMSCHM": dict(n=6),
    "MVT": dict(n=24),
    "SYR2K": dict(n=12),
    "SYRK": dict(n=12),
}


def fill(buf: int, size: int) -> jax.Array:
    """bench_suite::fill_value, vectorized: deterministic non-zero data.

    Quadratic term mirrors the rust side (keeps Gram-Schmidt inputs
    well-conditioned). Validation sizes stay < 2^15 elements so the i²·13
    term fits int32.
    """
    i = jnp.arange(size, dtype=jnp.int32)
    return ((i * i * 13 + i * 17 + buf * 31 + 7) % 101).astype(
        jnp.float32
    ) / 101.0 + 0.5


def fill2(buf: int, n: int) -> jax.Array:
    return fill(buf, n * n).reshape(n, n)


# ---------------------------------------------------------------- models
# Each model returns the tuple of *output* buffers (flattened), in the
# order of the rust benchmark's `outputs` indices.


def model_gemm():
    n = DIMS["GEMM"]["n"]
    a, b, c = fill2(0, n), fill2(1, n), fill2(2, n)
    c = BETA * c + ALPHA * matmul(a, b)
    return (c.reshape(-1),)


def model_2mm():
    n = DIMS["2MM"]["n"]
    a, b, c = fill2(0, n), fill2(1, n), fill2(2, n)
    tmp = ALPHA * matmul(a, b)
    dd = ALPHA * matmul(tmp, c)
    return (dd.reshape(-1),)


def model_3mm():
    n = DIMS["3MM"]["n"]
    a, b, c, dd = fill2(0, n), fill2(1, n), fill2(2, n), fill2(3, n)
    e = ALPHA * matmul(a, b)
    f = ALPHA * matmul(c, dd)
    g = ALPHA * matmul(e, f)
    return (g.reshape(-1),)


def model_atax():
    n = DIMS["ATAX"]["n"]
    a = fill2(0, n)
    x = fill(1, n)
    tmp = a @ x
    y = a.T @ tmp
    return (y,)


def model_bicg():
    n = DIMS["BICG"]["n"]
    a = fill2(0, n)
    p = fill(1, n)
    r = fill(3, n)
    s = a.T @ r
    q = a @ p
    return (q, s)


def model_mvt():
    n = DIMS["MVT"]["n"]
    a = fill2(0, n)
    x1, x2 = fill(1, n), fill(2, n)
    y1, y2 = fill(3, n), fill(4, n)
    x1 = x1 + a @ y1
    x2 = x2 + a.T @ y2
    return (x1, x2)


def model_gesummv():
    n = DIMS["GESUMMV"]["n"]
    a, b = fill2(0, n), fill2(1, n)
    x = fill(2, n)
    tmp = a @ x
    y = ALPHA * tmp + BETA * (b @ x)
    return (y,)


def model_syrk():
    n = DIMS["SYRK"]["n"]
    a, c = fill2(0, n), fill2(1, n)
    c = BETA * c + ALPHA * matmul(a, a.T)
    return (c.reshape(-1),)


def model_syr2k():
    n = DIMS["SYR2K"]["n"]
    a, b, c = fill2(0, n), fill2(1, n), fill2(2, n)
    c = BETA * c + ALPHA * (matmul(a, b.T) + matmul(b, a.T))
    return (c.reshape(-1),)


def model_gramschm():
    n = DIMS["GRAMSCHM"]["n"]
    a = fill2(0, n)
    r = fill2(1, n)
    q = fill2(2, n)
    for k in range(n):
        rkk = jnp.sqrt(jnp.sum(a[:, k] * a[:, k]))
        r = r.at[k, k].set(rkk)
        q = q.at[:, k].set(a[:, k] / rkk)
        for j in range(k + 1, n):
            rkj = q[:, k] @ a[:, j]
            r = r.at[k, j].set(rkj)
            a = a.at[:, j].set(a[:, j] - q[:, k] * rkj)
    return (a.reshape(-1), q.reshape(-1))


def model_corr():
    n = DIMS["CORR"]["n"]
    data = fill2(0, n)
    sym_init = fill2(3, n)
    mean = jnp.sum(data, axis=0) / n
    var = jnp.sum((data - mean) ** 2, axis=0) / n
    std = jnp.sqrt(var)
    std = jnp.where(std <= EPS, 1.0, std)
    data = (data - mean) / (jnp.sqrt(jnp.float32(n)) * std)
    prod = matmul(data.T, data)
    eye = jnp.eye(n, dtype=bool)
    sym = jnp.where(eye, 1.0, prod)
    # the corr grid has n-1 threads: the last diagonal element is never
    # written and keeps its initialization
    sym = sym.at[n - 1, n - 1].set(sym_init[n - 1, n - 1])
    return (sym.reshape(-1),)


def model_covar():
    n = DIMS["COVAR"]["n"]
    data = fill2(0, n)
    mean = jnp.sum(data, axis=0) / n
    data = data - mean
    sym = matmul(data.T, data)
    return (sym.reshape(-1),)


def model_2dconv():
    n = DIMS["2DCONV"]["n"]
    a = fill2(0, n)
    b0 = fill2(1, n)
    w = [
        (-1, -1, 0.2), (-1, 0, -0.3), (-1, 1, 0.4),
        (0, -1, 0.5), (0, 0, 0.6), (0, 1, 0.7),
        (1, -1, -0.8), (1, 0, -0.9), (1, 1, 0.1),
    ]
    interior = jnp.zeros((n - 2, n - 2), dtype=jnp.float32)
    for di, dj, c in w:
        interior = interior + c * a[1 + di : n - 1 + di, 1 + dj : n - 1 + dj]
    b = b0.at[1 : n - 1, 1 : n - 1].set(interior)
    return (b.reshape(-1),)


def model_3dconv():
    n = DIMS["3DCONV"]["n"]
    a = fill(0, n * n * n).reshape(n, n, n)
    b0 = fill(1, n * n * n).reshape(n, n, n)
    offsets = [
        (-1, -1, -1, 0.2), (0, -1, -1, -0.3), (1, -1, 0, 0.4),
        (-1, 0, 0, 0.5), (0, 0, 0, 0.6), (1, 0, 1, 0.7),
        (-1, 1, 1, -0.8), (0, 1, 1, -0.9), (1, 1, -1, 0.1),
    ]
    interior = jnp.zeros((n - 2, n - 2, n - 2), dtype=jnp.float32)
    for di, dj, dk, c in offsets:
        interior = interior + c * a[
            1 + di : n - 1 + di, 1 + dj : n - 1 + dj, 1 + dk : n - 1 + dk
        ]
    b = b0.at[1 : n - 1, 1 : n - 1, 1 : n - 1].set(interior)
    return (b.reshape(-1),)


def model_fdtd2d():
    cfg = DIMS["FDTD-2D"]
    n, tmax = cfg["n"], cfg["tmax"]
    fict = fill(0, tmax)
    ex = fill2(1, n)
    ey = fill2(2, n)
    hz = fill2(3, n)
    rows = jnp.arange(n)[:, None]
    cols = jnp.arange(n)[None, :]
    for t in range(tmax):
        # kernel1: ey
        hz_up = jnp.roll(hz, 1, axis=0)  # hz[i-1, j]; row 0 is masked out
        ey = jnp.where(rows == 0, fict[t], ey - 0.5 * (hz - hz_up))
        # kernel2: ex (j > 0)
        hz_left = jnp.roll(hz, 1, axis=1)
        ex = jnp.where(cols > 0, ex - 0.5 * (hz - hz_left), ex)
        # kernel3: hz (i < n-1, j < n-1) — uses the UPDATED ex/ey
        ex_right = jnp.roll(ex, -1, axis=1)
        ey_down = jnp.roll(ey, -1, axis=0)
        upd = hz - 0.7 * (ex_right - ex + ey_down - ey)
        hz = jnp.where((rows < n - 1) & (cols < n - 1), upd, hz)
    return (ex.reshape(-1), ey.reshape(-1), hz.reshape(-1))


MODELS = {
    "2DCONV": model_2dconv,
    "3DCONV": model_3dconv,
    "2MM": model_2mm,
    "3MM": model_3mm,
    "ATAX": model_atax,
    "BICG": model_bicg,
    "CORR": model_corr,
    "COVAR": model_covar,
    "FDTD-2D": model_fdtd2d,
    "GEMM": model_gemm,
    "GESUMMV": model_gesummv,
    "GRAMSCHM": model_gramschm,
    "MVT": model_mvt,
    "SYR2K": model_syr2k,
    "SYRK": model_syrk,
}
