"""Pure-jnp oracle for the Pallas kernel — the CORE correctness signal
for L1. Anything `matmul.py` computes must match this within f32 noise.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
