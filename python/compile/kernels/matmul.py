"""L1 — Pallas tiled matmul kernel.

The compute hot-spot of the matmul-family golden models (GEMM, 2MM, 3MM,
SYRK, SYR2K, CORR/COVAR cross-products). Written TPU-style — the grid
tiles the output into (bm × bn) VMEM blocks, each program instance
contracts a full K panel on the MXU — but always lowered with
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls (see DESIGN.md §Hardware-Adaptation).

The kernel is validated against the pure-jnp oracle in ``ref.py`` by
``python/tests/test_kernel.py`` (hypothesis sweep over shapes/seeds).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int = 128) -> int:
    """Largest power-of-two divisor of ``dim`` up to ``preferred``.

    On a real TPU we would pad to 128×128 MXU tiles; under interpret mode
    we keep exact tiling so tiny validation shapes work unpadded.
    """
    b = 1
    while b * 2 <= min(dim, preferred) and dim % (b * 2) == 0:
        b *= 2
    return b


def _mm_kernel(a_ref, b_ref, o_ref):
    # One (bm, K) × (K, bn) panel contraction per program instance.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B via the Pallas kernel (f32)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = _pick_block(m)
    bn = _pick_block(n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
