"""AOT lowering: every golden model → HLO *text* artifact.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the image's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single benchmark")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn in sorted(MODELS.items()):
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower()
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = fn()
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "num_outputs": len(outs),
            "output_sizes": [int(o.size) for o in outs],
        }
        print(f"lowered {name}: {len(text)} chars, {len(outs)} outputs")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
