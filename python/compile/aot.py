"""AOT lowering: every golden model → artifacts consumed by the rust DSE.

Two files per benchmark under --out-dir:

* ``<name>.hlo.txt`` — the jax-lowered HLO *text* (informational /
  external PJRT tooling). HLO text (not ``.serialize()``) is the
  interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
  which older xla_extension builds reject; the text parser reassigns ids
  and round-trips cleanly.
* ``<name>.golden.txt`` — the executed model's output buffers (one buffer
  per line, shortest-round-trip decimals). This is what
  ``rust/src/runtime`` reads at DSE time: the rust side is std-only, so
  the numbers are dumped here instead of executing HLO through PJRT
  bindings at exploration time.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os
import sys

import jax
import numpy as np

from .model import MODELS


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def dump_golden(outs, path: str) -> None:
    """One output buffer per line; repr() round-trips every f32 exactly."""
    with open(path, "w") as f:
        f.write("# golden outputs — one buffer per line (f32)\n")
        for o in outs:
            arr = np.asarray(o, dtype=np.float32).reshape(-1)
            # a blank line would be skipped by the rust parser, silently
            # shifting every later buffer; no model output may be empty
            assert arr.size > 0, f"empty output buffer in {path}"
            f.write(" ".join(repr(float(x)) for x in arr))
            f.write("\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single benchmark")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn in sorted(MODELS.items()):
        if args.only and name != args.only:
            continue
        outs = fn()
        golden_file = f"{name}.golden.txt"
        dump_golden(outs, os.path.join(args.out_dir, golden_file))
        hlo_file = None
        try:
            lowered = jax.jit(fn).lower()
            text = to_hlo_text(lowered)
            hlo_file = f"{name}.hlo.txt"
            with open(os.path.join(args.out_dir, hlo_file), "w") as f:
                f.write(text)
        except Exception as e:  # HLO text is informational; golden is not
            print(f"warning: {name}: HLO text lowering failed ({e})", file=sys.stderr)
        manifest[name] = {
            "golden_file": golden_file,
            "num_outputs": len(outs),
            "output_sizes": [int(o.size) for o in outs],
        }
        if hlo_file:
            manifest[name]["file"] = hlo_file
        print(
            f"{name}: golden {len(outs)} outputs, "
            + ("hlo ok" if hlo_file else "hlo FAILED")
        )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
