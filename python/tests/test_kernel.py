"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle,
swept over shapes/seeds with hypothesis (per the repro methodology:
hypothesis drives the kernel's shape/dtype space)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul, _pick_block
from compile.kernels.ref import matmul_ref


@settings(max_examples=40, deadline=None)
@given(
    m=st.sampled_from([2, 4, 6, 8, 10, 12, 16, 24]),
    k=st.sampled_from([2, 4, 6, 8, 10, 12, 16]),
    n=st.sampled_from([2, 4, 6, 8, 10, 12, 16, 20]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = jax.random.uniform(ka, (m, k), dtype=jnp.float32, minval=-2, maxval=2)
    b = jax.random.uniform(kb, (k, n), dtype=jnp.float32, minval=-2, maxval=2)
    got = matmul(a, b)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dim,expected", [(2, 2), (4, 4), (6, 2), (12, 4), (128, 128), (10, 2), (256, 128)])
def test_pick_block_divides(dim, expected):
    b = _pick_block(dim)
    assert dim % b == 0
    assert b == expected


def test_identity_matmul():
    n = 8
    eye = jnp.eye(n, dtype=jnp.float32)
    x = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
    np.testing.assert_allclose(np.asarray(matmul(eye, x)), np.asarray(x))


def test_odd_k_panel():
    # K need not be tiled; only M/N blocks matter
    a = jnp.ones((4, 7), dtype=jnp.float32)
    b = jnp.ones((7, 4), dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(matmul(a, b)), 7.0 * np.ones((4, 4)))
