"""L2 sanity: golden models produce finite outputs of the right shapes,
and the matmul-family models agree with direct jnp formulations."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ALPHA, BETA, DIMS, MODELS, fill, fill2


@pytest.mark.parametrize("name", sorted(MODELS))
def test_outputs_finite(name):
    outs = MODELS[name]()
    assert len(outs) >= 1
    for o in outs:
        arr = np.asarray(o)
        assert np.all(np.isfinite(arr)), name
        assert arr.dtype == np.float32


def test_gemm_formula():
    n = DIMS["GEMM"]["n"]
    a, b, c = fill2(0, n), fill2(1, n), fill2(2, n)
    want = BETA * c + ALPHA * (a @ b)
    got = MODELS["GEMM"]()[0].reshape(n, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_atax_formula():
    n = DIMS["ATAX"]["n"]
    a = fill2(0, n)
    x = fill(1, n)
    want = a.T @ (a @ x)
    got = MODELS["ATAX"]()[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_covar_symmetric():
    n = DIMS["COVAR"]["n"]
    got = MODELS["COVAR"]()[0].reshape(n, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got).T, rtol=1e-6)


def test_corr_diag():
    n = DIMS["CORR"]["n"]
    sym = np.asarray(MODELS["CORR"]()[0]).reshape(n, n)
    # diagonal is 1 except the never-written last element
    np.testing.assert_allclose(sym.diagonal()[:-1], 1.0)
    init = np.asarray(fill2(3, n))
    assert sym[n - 1, n - 1] == init[n - 1, n - 1]


def test_conv_border_untouched():
    n = DIMS["2DCONV"]["n"]
    b = np.asarray(MODELS["2DCONV"]()[0]).reshape(n, n)
    init = np.asarray(fill2(1, n))
    np.testing.assert_array_equal(b[0, :], init[0, :])
    np.testing.assert_array_equal(b[:, n - 1], init[:, n - 1])
    assert not np.array_equal(b[1:-1, 1:-1], init[1:-1, 1:-1])


def test_fill_matches_rust_formula():
    # spot values mirroring bench_suite::fill_value
    v = np.asarray(fill(2, 10))
    for i in range(10):
        want = ((i * i * 13 + i * 17 + 2 * 31 + 7) % 101) / 101.0 + 0.5
        assert abs(v[i] - want) < 1e-6
