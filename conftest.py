"""pytest bootstrap: make `compile.*` importable when pytest runs from
the repository root (`pytest python/tests/`) as well as from `python/`."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
